//! Strict, streaming JSON for the wire gateway — tokenizer, DOM
//! bridge, and an escaping writer with precise `f32` round-trips.
//!
//! The repo's [`util::json`](crate::util::json) codec is a trusting
//! DOM parser for files the repo itself writes (bench reports,
//! artifact manifests).  A network edge parses *adversarial* bytes, so
//! this module is a separate, hardened codec in the spirit of
//! picojson-rs:
//!
//! * **Pull tokenizer** ([`Tokenizer`]) — a grammar-validating event
//!   stream over a byte slice: the caller drains [`Event`]s and
//!   malformed input errors at the offending byte.  Strings borrow
//!   from the input when escape-free (no allocation on the hot path);
//!   numbers are parsed in place.  Enforced [`Limits`]: total input
//!   bytes, nesting depth, per-string raw length.
//! * **Strictness** — exact JSON grammar (no `01`, `+1`, `.5`, `1.`,
//!   trailing data, or bare control characters in strings), full
//!   UTF-8 validation of raw string spans, `\uXXXX` escapes with
//!   mandatory surrogate pairing, and rejection of numbers that
//!   overflow `f64` (`1e999` is an error, not `inf` — JSON cannot
//!   express the round-trip).
//! * **DOM bridge** ([`parse_value`]) — builds the shared
//!   [`Json`](crate::util::json::Json) value iteratively (no
//!   recursion, so hostile depth can never touch the thread stack
//!   even with custom limits).
//! * **Writer** ([`JsonWriter`]) — escaping, comma/colon-managing
//!   builder.  `f32` row payloads serialize via Rust's shortest
//!   round-trip `Display`, so every finite activation value survives
//!   HTTP bit-identically (`parse(fmt(x)) as f32 == x`, asserted by a
//!   property test); non-finite values become `null` (JSON has no
//!   spelling for them).

use std::borrow::Cow;
use std::fmt::Write as _;

use crate::util::json::Json;

/// Hard bounds the tokenizer enforces while scanning untrusted input.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Largest accepted input, in bytes (the HTTP layer also bounds
    /// bodies; this guards direct callers).
    pub max_bytes: usize,
    /// Deepest accepted container nesting.
    pub max_depth: usize,
    /// Longest accepted string token, in raw (escaped) bytes.
    pub max_string_bytes: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits {
            max_bytes: 8 << 20,
            max_depth: 64,
            max_string_bytes: 1 << 20,
        }
    }
}

/// One step of the event stream.  String data borrows from the input
/// whenever the token carries no escapes.
#[derive(Debug, PartialEq)]
pub enum Event<'a> {
    ObjBegin,
    ObjEnd,
    ArrBegin,
    ArrEnd,
    /// An object member's key (always followed by that member's value
    /// events — the tokenizer validates the `:`).
    Key(Cow<'a, str>),
    Str(Cow<'a, str>),
    Num(f64),
    Bool(bool),
    Null,
}

#[derive(Clone, Copy, PartialEq)]
enum Frame {
    Obj,
    Arr,
}

#[derive(Clone, Copy, PartialEq)]
enum Expect {
    /// A value (top level, after `:`, after `[` or array `,`).
    Value,
    /// `}` or the first key of an object.
    FirstKeyOrEnd,
    /// `,` (then a key) or `}`.
    ObjNext,
    /// `]` or the first value of an array.
    FirstValueOrEnd,
    /// `,` (then a value) or `]`.
    ArrNext,
    /// One complete top-level value consumed; only whitespace may
    /// remain.
    Done,
}

/// Grammar-validating pull tokenizer (see module docs).  `next()`
/// yields `Ok(Some(event))` until the single top-level value is
/// complete, then `Ok(None)` exactly once input is exhausted.
pub struct Tokenizer<'a> {
    b: &'a [u8],
    i: usize,
    stack: Vec<Frame>,
    expect: Expect,
    limits: Limits,
}

impl<'a> Tokenizer<'a> {
    pub fn new(b: &'a [u8], limits: &Limits) -> anyhow::Result<Tokenizer<'a>> {
        anyhow::ensure!(
            b.len() <= limits.max_bytes,
            "json input is {} bytes; limit is {}",
            b.len(),
            limits.max_bytes
        );
        Ok(Tokenizer {
            b,
            i: 0,
            stack: Vec::new(),
            expect: Expect::Value,
            limits: *limits,
        })
    }

    /// Byte offset of the scan head (error context for callers).
    pub fn pos(&self) -> usize {
        self.i
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| {
            anyhow::anyhow!("unexpected end of json at byte {}", self.i)
        })
    }

    fn bad(&self, what: &str) -> anyhow::Error {
        match self.b.get(self.i) {
            Some(c) if c.is_ascii_graphic() => anyhow::anyhow!(
                "expected {what} at byte {}, found `{}`",
                self.i,
                *c as char
            ),
            Some(c) => anyhow::anyhow!(
                "expected {what} at byte {}, found byte 0x{c:02x}",
                self.i
            ),
            None => anyhow::anyhow!(
                "expected {what} at byte {}, found end of input",
                self.i
            ),
        }
    }

    /// State after a complete value: back to the enclosing container's
    /// separator state, or `Done` at top level.
    fn after_value(&self) -> Expect {
        match self.stack.last() {
            None => Expect::Done,
            Some(Frame::Obj) => Expect::ObjNext,
            Some(Frame::Arr) => Expect::ArrNext,
        }
    }

    fn push(&mut self, f: Frame) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.stack.len() < self.limits.max_depth,
            "json nesting exceeds the depth limit of {} at byte {}",
            self.limits.max_depth,
            self.i
        );
        self.stack.push(f);
        Ok(())
    }

    /// Next event, `Ok(None)` exactly at clean end of input.
    #[allow(clippy::should_implement_trait)] // fallible, not an Iterator
    pub fn next(&mut self) -> anyhow::Result<Option<Event<'a>>> {
        loop {
            self.ws();
            match self.expect {
                Expect::Done => {
                    anyhow::ensure!(
                        self.i == self.b.len(),
                        "trailing data after the json value at byte {}",
                        self.i
                    );
                    return Ok(None);
                }
                Expect::Value => return self.value().map(Some),
                Expect::FirstKeyOrEnd => {
                    if self.peek()? == b'}' {
                        self.i += 1;
                        self.stack.pop();
                        self.expect = self.after_value();
                        return Ok(Some(Event::ObjEnd));
                    }
                    return self.key().map(Some);
                }
                Expect::ObjNext => match self.peek()? {
                    b',' => {
                        self.i += 1;
                        self.ws();
                        return self.key().map(Some);
                    }
                    b'}' => {
                        self.i += 1;
                        self.stack.pop();
                        self.expect = self.after_value();
                        return Ok(Some(Event::ObjEnd));
                    }
                    _ => return Err(self.bad("`,` or `}`")),
                },
                Expect::FirstValueOrEnd => {
                    if self.peek()? == b']' {
                        self.i += 1;
                        self.stack.pop();
                        self.expect = self.after_value();
                        return Ok(Some(Event::ArrEnd));
                    }
                    self.expect = Expect::Value;
                    continue;
                }
                Expect::ArrNext => match self.peek()? {
                    b',' => {
                        self.i += 1;
                        self.expect = Expect::Value;
                        continue;
                    }
                    b']' => {
                        self.i += 1;
                        self.stack.pop();
                        self.expect = self.after_value();
                        return Ok(Some(Event::ArrEnd));
                    }
                    _ => return Err(self.bad("`,` or `]`")),
                },
            }
        }
    }

    fn key(&mut self) -> anyhow::Result<Event<'a>> {
        anyhow::ensure!(self.peek()? == b'"', "{}", self.bad("a string key"));
        let k = self.string()?;
        self.ws();
        anyhow::ensure!(self.peek()? == b':', "{}", self.bad("`:`"));
        self.i += 1;
        self.expect = Expect::Value;
        Ok(Event::Key(k))
    }

    fn value(&mut self) -> anyhow::Result<Event<'a>> {
        match self.peek()? {
            b'{' => {
                self.i += 1;
                self.push(Frame::Obj)?;
                self.expect = Expect::FirstKeyOrEnd;
                Ok(Event::ObjBegin)
            }
            b'[' => {
                self.i += 1;
                self.push(Frame::Arr)?;
                self.expect = Expect::FirstValueOrEnd;
                Ok(Event::ArrBegin)
            }
            b'"' => {
                let s = self.string()?;
                self.expect = self.after_value();
                Ok(Event::Str(s))
            }
            b't' => self.lit("true", Event::Bool(true)),
            b'f' => self.lit("false", Event::Bool(false)),
            b'n' => self.lit("null", Event::Null),
            b'-' | b'0'..=b'9' => {
                let n = self.number()?;
                self.expect = self.after_value();
                Ok(Event::Num(n))
            }
            _ => Err(self.bad("a json value")),
        }
    }

    fn lit(&mut self, s: &str, ev: Event<'a>) -> anyhow::Result<Event<'a>> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        self.expect = self.after_value();
        Ok(ev)
    }

    /// Strict number grammar: `-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?
    /// [0-9]+)?`, rejected when the parsed value overflows `f64`.
    fn number(&mut self) -> anyhow::Result<f64> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        match self.peek().map_err(|_| self.bad("a digit"))? {
            b'0' => self.i += 1,
            b'1'..=b'9' => {
                while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                    self.i += 1;
                }
            }
            _ => return Err(self.bad("a digit")),
        }
        if self.b.get(self.i) == Some(&b'.') {
            self.i += 1;
            anyhow::ensure!(
                matches!(self.b.get(self.i), Some(b'0'..=b'9')),
                "{}",
                self.bad("a fraction digit")
            );
            while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.b.get(self.i), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.b.get(self.i), Some(b'+' | b'-')) {
                self.i += 1;
            }
            anyhow::ensure!(
                matches!(self.b.get(self.i), Some(b'0'..=b'9')),
                "{}",
                self.bad("an exponent digit")
            );
            while matches!(self.b.get(self.i), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        // The slice is ASCII by construction.
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let v: f64 = s
            .parse()
            .map_err(|e| anyhow::anyhow!("bad number `{s}`: {e}"))?;
        anyhow::ensure!(
            v.is_finite(),
            "number `{s}` at byte {start} overflows f64"
        );
        Ok(v)
    }

    /// Strict string: full UTF-8 validation of raw spans, escape
    /// decoding with mandatory surrogate pairing, raw control bytes
    /// rejected.  Borrows when escape-free.
    fn string(&mut self) -> anyhow::Result<Cow<'a, str>> {
        debug_assert_eq!(self.b[self.i], b'"');
        self.i += 1;
        let start = self.i;
        let mut owned: Option<String> = None;
        let mut span = start; // start of the current raw (unescaped) run
        loop {
            anyhow::ensure!(
                self.i - start <= self.limits.max_string_bytes,
                "string starting at byte {} exceeds the {}-byte limit",
                start - 1,
                self.limits.max_string_bytes
            );
            let c = self.peek()?;
            match c {
                b'"' => {
                    let tail = self.raw_span(span, self.i)?;
                    self.i += 1;
                    return Ok(match owned {
                        None => Cow::Borrowed(tail),
                        Some(mut s) => {
                            s.push_str(tail);
                            Cow::Owned(s)
                        }
                    });
                }
                b'\\' => {
                    let tail = self.raw_span(span, self.i)?;
                    let out = owned.get_or_insert_with(String::new);
                    out.push_str(tail);
                    self.i += 1;
                    self.escape(out)?;
                    span = self.i;
                }
                0x00..=0x1f => {
                    anyhow::bail!(
                        "raw control byte 0x{c:02x} in string at byte {} \
                         (escape it)",
                        self.i
                    );
                }
                _ => self.i += 1,
            }
        }
    }

    /// Validate one raw (escape-free) span as UTF-8.
    fn raw_span(&self, from: usize, to: usize) -> anyhow::Result<&'a str> {
        std::str::from_utf8(&self.b[from..to]).map_err(|e| {
            anyhow::anyhow!(
                "invalid utf-8 in string near byte {}: {e}",
                from + e.valid_up_to()
            )
        })
    }

    /// Decode one escape sequence (the `\` is already consumed).
    fn escape(&mut self, out: &mut String) -> anyhow::Result<()> {
        let e = self.peek()?;
        self.i += 1;
        match e {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{8}'),
            b'f' => out.push('\u{c}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let hi = self.hex4()?;
                let cp = match hi {
                    0xd800..=0xdbff => {
                        // High surrogate: a low one must follow.
                        anyhow::ensure!(
                            self.b.get(self.i) == Some(&b'\\')
                                && self.b.get(self.i + 1) == Some(&b'u'),
                            "unpaired high surrogate \\u{hi:04x} at byte {}",
                            self.i
                        );
                        self.i += 2;
                        let lo = self.hex4()?;
                        anyhow::ensure!(
                            (0xdc00..=0xdfff).contains(&lo),
                            "\\u{hi:04x} must pair with a low surrogate, \
                             got \\u{lo:04x}"
                        );
                        0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                    }
                    0xdc00..=0xdfff => anyhow::bail!(
                        "lone low surrogate \\u{hi:04x} at byte {}",
                        self.i
                    ),
                    cp => cp,
                };
                out.push(char::from_u32(cp).ok_or_else(|| {
                    anyhow::anyhow!("escape \\u decodes to invalid \
                                     scalar 0x{cp:x}")
                })?);
            }
            _ => anyhow::bail!("bad escape `\\{}` at byte {}",
                               if e.is_ascii_graphic() {
                                   (e as char).to_string()
                               } else {
                                   format!("x{e:02x}")
                               },
                               self.i - 1),
        }
        Ok(())
    }

    fn hex4(&mut self) -> anyhow::Result<u32> {
        let end = self.i.checked_add(4).filter(|&e| e <= self.b.len());
        let end = end.ok_or_else(|| {
            anyhow::anyhow!("truncated \\u escape at byte {}", self.i)
        })?;
        let s = std::str::from_utf8(&self.b[self.i..end])
            .map_err(|_| anyhow::anyhow!("non-ascii \\u escape"))?;
        // Exactly four hex digits — from_str_radix alone would also
        // accept a sign (`+041`), which no JSON grammar allows.
        anyhow::ensure!(
            s.bytes().all(|b| b.is_ascii_hexdigit()),
            "bad \\u escape `{s}` at byte {}",
            self.i
        );
        let v = u32::from_str_radix(s, 16).map_err(|_| {
            anyhow::anyhow!("bad \\u escape `{s}` at byte {}", self.i)
        })?;
        self.i = end;
        Ok(v)
    }
}

/// Parse one complete value into the shared DOM, iteratively (hostile
/// depth can never touch the thread stack).
pub fn parse_value(b: &[u8], limits: &Limits) -> anyhow::Result<Json> {
    enum Holder {
        Arr(Vec<Json>),
        Obj(std::collections::BTreeMap<String, Json>, Option<String>),
    }
    let mut tok = Tokenizer::new(b, limits)?;
    let mut stack: Vec<Holder> = Vec::new();
    let mut root: Option<Json> = None;
    while let Some(ev) = tok.next()? {
        let done: Option<Json> = match ev {
            Event::ObjBegin => {
                stack.push(Holder::Obj(Default::default(), None));
                None
            }
            Event::ArrBegin => {
                stack.push(Holder::Arr(Vec::new()));
                None
            }
            Event::Key(k) => {
                match stack.last_mut() {
                    Some(Holder::Obj(_, slot)) => *slot = Some(k.into_owned()),
                    // The tokenizer only emits Key inside an object,
                    // but a malformed event stream degrades to a parse
                    // error rather than a worker abort.
                    _ => anyhow::bail!("json key outside an object"),
                }
                None
            }
            Event::ObjEnd | Event::ArrEnd => match stack.pop() {
                Some(Holder::Obj(m, _)) => Some(Json::Obj(m)),
                Some(Holder::Arr(a)) => Some(Json::Arr(a)),
                None => anyhow::bail!("unbalanced json container close"),
            },
            Event::Str(s) => Some(Json::Str(s.into_owned())),
            Event::Num(n) => Some(Json::Num(n)),
            Event::Bool(v) => Some(Json::Bool(v)),
            Event::Null => Some(Json::Null),
        };
        if let Some(v) = done {
            match stack.last_mut() {
                None => root = Some(v),
                Some(Holder::Arr(a)) => a.push(v),
                Some(Holder::Obj(m, slot)) => match slot.take() {
                    Some(k) => {
                        m.insert(k, v);
                    }
                    None => anyhow::bail!("json member value without key"),
                },
            }
        }
    }
    root.ok_or_else(|| anyhow::anyhow!("empty json input"))
}

/// Escaping, comma/colon-managing response builder (see module docs).
/// Misuse (a value where a key is due, unclosed containers at
/// `finish`) panics — the wire handlers are the only writers and their
/// shapes are static.
#[derive(Default)]
pub struct JsonWriter {
    out: String,
    /// (is_object, item_count) per open container.
    stack: Vec<(bool, usize)>,
    /// A key was just written; the next value takes no comma.
    keyed: bool,
}

impl JsonWriter {
    pub fn new() -> JsonWriter {
        JsonWriter::default()
    }

    /// Bytes written so far (admission for streaming writers).
    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }

    fn pre_value(&mut self) {
        if self.keyed {
            self.keyed = false;
            return;
        }
        if let Some((is_obj, count)) = self.stack.last_mut() {
            assert!(!*is_obj, "object members need a key first");
            if *count > 0 {
                self.out.push(',');
            }
            *count += 1;
        }
    }

    pub fn begin_obj(&mut self) -> &mut Self {
        self.pre_value();
        self.out.push('{');
        self.stack.push((true, 0));
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        let frame = self.stack.pop();
        assert!(matches!(frame, Some((true, _))), "end_obj without obj");
        self.out.push('}');
        self
    }

    pub fn begin_arr(&mut self) -> &mut Self {
        self.pre_value();
        self.out.push('[');
        self.stack.push((false, 0));
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        let frame = self.stack.pop();
        assert!(matches!(frame, Some((false, _))), "end_arr without arr");
        self.out.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        let (is_obj, count) = self
            .stack
            .last_mut()
            // lint: allow(panic) — documented builder contract (see type docs): misuse by a handler is a programming error caught by the wire tests, exactly like the asserts beside it.
            .expect("key outside any container");
        assert!(*is_obj, "key inside an array");
        if *count > 0 {
            self.out.push(',');
        }
        *count += 1;
        write_escaped(k, &mut self.out);
        self.out.push(':');
        self.keyed = true;
        self
    }

    pub fn str_val(&mut self, s: &str) -> &mut Self {
        self.pre_value();
        write_escaped(s, &mut self.out);
        self
    }

    pub fn bool_val(&mut self, v: bool) -> &mut Self {
        self.pre_value();
        self.out.push_str(if v { "true" } else { "false" });
        self
    }

    pub fn null_val(&mut self) -> &mut Self {
        self.pre_value();
        self.out.push_str("null");
        self
    }

    pub fn u64_val(&mut self, v: u64) -> &mut Self {
        self.pre_value();
        let _ = write!(self.out, "{v}");
        self
    }

    pub fn f64_val(&mut self, v: f64) -> &mut Self {
        self.pre_value();
        if v.is_finite() {
            let _ = write!(self.out, "{v}");
        } else {
            self.out.push_str("null");
        }
        self
    }

    /// Shortest round-trip serialization: parsing the emitted decimal
    /// back (through f64, as JSON readers do) recovers `v` bit for
    /// bit for every finite f32.  Non-finite values emit `null`.
    pub fn f32_val(&mut self, v: f32) -> &mut Self {
        self.pre_value();
        if v.is_finite() {
            let _ = write!(self.out, "{v}");
        } else {
            self.out.push_str("null");
        }
        self
    }

    /// The finished document (panics on unclosed containers).
    pub fn finish(self) -> String {
        assert!(self.stack.is_empty(), "unclosed json container");
        self.out
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Pcg64;
    use crate::util::prop;

    fn parse(s: &str) -> anyhow::Result<Json> {
        parse_value(s.as_bytes(), &Limits::default())
    }

    fn parse_bytes(b: &[u8]) -> anyhow::Result<Json> {
        parse_value(b, &Limits::default())
    }

    #[test]
    fn accepts_the_grammar() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-0.5e2").unwrap(), Json::Num(-50.0));
        assert_eq!(parse("\"a\\u0041\"").unwrap(), Json::Str("aA".into()));
        let j = parse(r#" {"a": [1, 2.5, {"b": "x"}], "c": null} "#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_malformed_structure() {
        for bad in [
            "", "{", "}", "[1,]", "{\"a\":}", "{\"a\"}", "{a:1}",
            "[1 2]", "12 34", "true false", "nul", "truex", "[,1]",
            "{\"a\":1,}", "\"unterminated", "[1]]", "{{}}",
        ] {
            assert!(parse(bad).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn rejects_malformed_numbers_and_huge_values() {
        for bad in ["01", "+1", ".5", "1.", "-", "--1", "1e", "1e+",
                    "0x10", "NaN", "Infinity", "1e999", "-1e999"] {
            assert!(parse(bad).is_err(), "must reject number: {bad}");
        }
        // large-but-representable values parse
        assert_eq!(parse("1e308").unwrap(), Json::Num(1e308));
        let long = "123456789012345678901234567890";
        assert_eq!(parse(long).unwrap(),
                   Json::Num(1.2345678901234568e29));
    }

    #[test]
    fn rejects_malformed_utf8_and_raw_controls() {
        // invalid start byte, truncated multibyte, overlong encoding,
        // bare surrogate encoding
        for bad in [
            b"\"\xff\"".as_slice(),
            b"\"\xe2\x82\"".as_slice(),
            b"\"\xc0\x80\"".as_slice(),
            b"\"\xed\xa0\x80\"".as_slice(),
        ] {
            assert!(parse_bytes(bad).is_err(), "must reject: {bad:?}");
        }
        assert!(parse_bytes(b"\"a\x01b\"").is_err(),
                "raw control chars must be escaped");
        assert!(parse_bytes(b"\"a\nb\"").is_err(),
                "raw newline must be escaped");
        // valid multibyte passes, borrowed or not
        assert_eq!(parse("\"δ_s ΔW\"").unwrap(), Json::Str("δ_s ΔW".into()));
    }

    #[test]
    fn surrogate_escapes_must_pair() {
        assert_eq!(parse(r#""😀""#).unwrap(),
                   Json::Str("😀".into()));
        for bad in [r#""\ud83d""#, r#""\ud83dx""#, r#""\ud83dA""#,
                    r#""\ude00""#, r#""\u12"#, r#""\uzzzz""#,
                    r#""\u+041""#, r#""\u-041""#] {
            assert!(parse(bad).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn depth_limit_holds() {
        let limits = Limits { max_depth: 8, ..Limits::default() };
        let ok = "[".repeat(8) + &"]".repeat(8);
        assert!(parse_value(ok.as_bytes(), &limits).is_ok());
        let deep = "[".repeat(9) + &"]".repeat(9);
        let err = parse_value(deep.as_bytes(), &limits).unwrap_err();
        assert!(err.to_string().contains("depth"), "{err}");
        // hostile depth with huge limits must not touch the thread
        // stack (iterative DOM build)
        let hostile = "[".repeat(100_000) + &"]".repeat(100_000);
        let loose = Limits { max_depth: usize::MAX, ..Limits::default() };
        assert!(parse_value(hostile.as_bytes(), &loose).is_ok());
    }

    #[test]
    fn size_limits_hold() {
        let limits = Limits { max_bytes: 16, ..Limits::default() };
        assert!(parse_value(b"[1,2,3]", &limits).is_ok());
        assert!(parse_value(b"[1,2,3,4,5,6,7,8]", &limits).is_err());
        let limits = Limits { max_string_bytes: 4, ..Limits::default() };
        assert!(parse_value(b"\"abcd\"", &limits).is_ok());
        assert!(parse_value(b"\"abcdef\"", &limits).is_err());
    }

    #[test]
    fn truncated_bodies_error_at_every_cut() {
        let doc = br#"{"adapter":"aA","rows":[[1.5,-2e-3,0]]}"#;
        for cut in 1..doc.len() {
            assert!(
                parse_bytes(&doc[..cut]).is_err(),
                "prefix of {cut} bytes must not parse"
            );
        }
        assert!(parse_bytes(doc).is_ok());
    }

    #[test]
    fn strings_borrow_when_escape_free() {
        let b = br#"["plain", "esc\n"]"#;
        let mut tok = Tokenizer::new(b, &Limits::default()).unwrap();
        assert_eq!(tok.next().unwrap(), Some(Event::ArrBegin));
        match tok.next().unwrap().unwrap() {
            Event::Str(Cow::Borrowed(s)) => assert_eq!(s, "plain"),
            other => panic!("expected borrowed str, got {other:?}"),
        }
        match tok.next().unwrap().unwrap() {
            Event::Str(Cow::Owned(s)) => assert_eq!(s, "esc\n"),
            other => panic!("expected owned str, got {other:?}"),
        }
        assert_eq!(tok.next().unwrap(), Some(Event::ArrEnd));
        assert_eq!(tok.next().unwrap(), None);
    }

    #[test]
    fn f32_round_trips_bit_exactly() {
        // Random bit patterns (finite only) survive write -> parse ->
        // `as f32` unchanged — the wire contract for row payloads.
        prop::for_all("f32 json round-trip", 2000, |rng| {
            let bits = rng.next_u64() as u32;
            let v = f32::from_bits(bits);
            if !v.is_finite() {
                return;
            }
            let mut w = JsonWriter::new();
            w.f32_val(v);
            let s = w.finish();
            let back = match parse(&s).unwrap() {
                Json::Num(n) => n as f32,
                other => panic!("{other:?}"),
            };
            assert_eq!(back.to_bits(), v.to_bits(),
                       "{v:?} -> `{s}` -> {back:?}");
        });
        // the edge cases worth pinning explicitly
        for v in [0.0f32, -0.0, f32::MIN_POSITIVE, 1e-45, f32::MAX,
                  f32::MIN, 1.0 + f32::EPSILON] {
            let mut w = JsonWriter::new();
            w.f32_val(v);
            let back = match parse(&w.finish()).unwrap() {
                Json::Num(n) => n as f32,
                other => panic!("{other:?}"),
            };
            assert_eq!(back.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn non_finite_writes_null() {
        let mut w = JsonWriter::new();
        w.begin_arr()
            .f32_val(f32::NAN)
            .f32_val(f32::INFINITY)
            .f64_val(f64::NEG_INFINITY)
            .end_arr();
        assert_eq!(w.finish(), "[null,null,null]");
    }

    #[test]
    fn writer_builds_and_escapes_documents() {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("name").str_val("a\"b\\c\nd");
        w.key("n").u64_val(42);
        w.key("ok").bool_val(true);
        w.key("none").null_val();
        w.key("rows").begin_arr();
        w.begin_arr().f32_val(1.5).f32_val(-0.25).end_arr();
        w.begin_arr().end_arr();
        w.end_arr();
        w.end_obj();
        let s = w.finish();
        assert_eq!(
            s,
            "{\"name\":\"a\\\"b\\\\c\\nd\",\"n\":42,\"ok\":true,\
             \"none\":null,\"rows\":[[1.5,-0.25],[]]}"
        );
        // and the strict parser accepts its own writer's output
        let j = parse(&s).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("a\"b\\c\nd"));
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn tokenizer_streams_rows_without_dom() {
        // The /v1/forward hot path: numbers pulled straight off the
        // tokenizer into typed vectors.
        let b = br#"{"rows":[[1,2],[3,4,5]]}"#;
        let mut tok = Tokenizer::new(b, &Limits::default()).unwrap();
        assert_eq!(tok.next().unwrap(), Some(Event::ObjBegin));
        assert!(matches!(tok.next().unwrap(), Some(Event::Key(k))
                         if k == "rows"));
        assert_eq!(tok.next().unwrap(), Some(Event::ArrBegin));
        let mut rows: Vec<Vec<f64>> = Vec::new();
        loop {
            match tok.next().unwrap().unwrap() {
                Event::ArrBegin => rows.push(Vec::new()),
                Event::Num(n) => rows.last_mut().unwrap().push(n),
                Event::ArrEnd => {
                    if rows.last().is_none() {
                        break;
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
            if rows.len() == 2 && rows[1].len() == 3 {
                break;
            }
        }
        assert_eq!(rows, vec![vec![1.0, 2.0], vec![3.0, 4.0, 5.0]]);
    }

    #[test]
    fn property_random_valid_documents_round_trip() {
        // Generate random DOM values, write them with the (trusted)
        // util writer, and require the strict parser to accept and
        // reproduce them.
        fn gen(rng: &mut Pcg64, depth: usize) -> Json {
            match prop::int_in(rng, 0, if depth == 0 { 3 } else { 5 }) {
                0 => Json::Null,
                1 => Json::Bool(rng.uniform() < 0.5),
                2 => Json::Num((rng.normal() * 100.0 * 2f64.powi(
                    prop::int_in(rng, 0, 20) as i32 - 10)).round()
                    / 1024.0),
                3 => {
                    let n = prop::int_in(rng, 0, 8);
                    Json::Str((0..n).map(|_| {
                        ['a', 'δ', '"', '\\', '\n', '😀', ' ', '\t']
                            [prop::int_in(rng, 0, 7)]
                    }).collect())
                }
                4 => Json::Arr((0..prop::int_in(rng, 0, 4))
                    .map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj((0..prop::int_in(rng, 0, 4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect()),
            }
        }
        prop::for_all("strict parser accepts valid docs", 200, |rng| {
            let doc = gen(rng, 3);
            let s = doc.to_string();
            let back = parse(&s).unwrap_or_else(|e| {
                panic!("strict parser rejected `{s}`: {e}")
            });
            assert_eq!(back, doc, "round-trip changed `{s}`");
        });
    }
}
