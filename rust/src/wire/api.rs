//! The gateway's JSON endpoints (see the [`wire`](crate::wire) module
//! docs for the route list).  Every handler is a pure function of
//! (shared gateway state, parsed request) → response; the HTTP layer
//! owns framing and the 413/503 transport errors, this layer owns the
//! API semantics: strict body parsing (400), adapter resolution (404),
//! class-tiered admission control (429 + `Retry-After`; the optional
//! `"class"` key maps to a QoS tier — `interactive` (default) /
//! `batch` / `background` — with lower tiers shedding earlier and the
//! scheduler weighting boarding by class), scheduler deadline expiries
//! (504), and drain-time refusals (503).

use std::borrow::Cow;
use std::sync::atomic::Ordering;

use crate::obs::{self, Outcome, Stage, Trace};
use crate::serve::RequestClass;
use crate::wire::gateway::GatewayState;
use crate::wire::http::{Request, Response};
use crate::wire::json::{Event, JsonWriter, Tokenizer};

/// Route one request.  Unknown paths are 404, known paths with the
/// wrong verb 405.
pub fn handle(state: &GatewayState, req: &Request) -> Response {
    let segs: Vec<&str> =
        req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => healthz(state),
        ("GET", ["metrics"]) => metrics(state),
        ("GET", ["v1", "stats"]) => stats(state),
        ("GET", ["v1", "debug", "slow"]) => debug_slow(state),
        ("GET", ["v1", "adapters"]) => list_adapters(state),
        ("POST", ["v1", "forward"]) => forward(state, req),
        ("POST", ["v1", "adapters", name, "load"]) => {
            load_adapter(state, name, req)
        }
        ("DELETE", ["v1", "adapters", name]) => evict_adapter(state, name),
        (_, ["healthz"])
        | (_, ["metrics"])
        | (_, ["v1", "stats"])
        | (_, ["v1", "debug", "slow"])
        | (_, ["v1", "forward"])
        | (_, ["v1", "adapters"])
        | (_, ["v1", "adapters", _, "load"])
        | (_, ["v1", "adapters", _]) => Response::error(
            405,
            &format!("method {} not allowed here", req.method),
        ),
        _ => Response::error(404, &format!("no route for {}", req.path)),
    }
}

fn healthz(state: &GatewayState) -> Response {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("status").str_val(if state.is_draining() {
        "draining"
    } else {
        "ok"
    });
    w.key("adapters").u64_val(state.adapter_count() as u64);
    w.end_obj();
    Response::json(200, w.finish())
}

fn stats(state: &GatewayState) -> Response {
    let sched = state.server().scheduler_stats();
    let (cache, cache_bytes, by_kind, cache_quant, adapters, method_of) = {
        let model = state.model();
        let m = model.lock().unwrap_or_else(|p| p.into_inner());
        let method_of: std::collections::BTreeMap<String, &'static str> =
            m.adapters()
                .map(|a| (a.name.to_string(), a.method.name()))
                .collect();
        (
            m.cache_stats(),
            m.cache_bytes(),
            m.cache_bytes_by_kind(),
            m.cache_quant().name(),
            m.len(),
            method_of,
        )
    };
    // Per-method rollup: adapters currently loaded and requests
    // submitted under each method (evicted adapters' request counts
    // survive in per_adapter but no longer map to a method).
    let mut methods: std::collections::BTreeMap<&str, (u64, u64)> =
        std::collections::BTreeMap::new();
    for name in method_of.values() {
        methods.entry(name).or_insert((0, 0)).0 += 1;
    }
    for (name, count) in &sched.per_adapter {
        if let Some(meth) = method_of.get(name) {
            methods.entry(meth).or_insert((0, 0)).1 += count;
        }
    }
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("adapters").u64_val(adapters as u64);
    w.key("queue_depth").u64_val(sched.queue_depth);
    w.key("submitted").u64_val(sched.submitted);
    w.key("batches").u64_val(sched.batches);
    w.key("batched_rows").u64_val(sched.batched_rows);
    w.key("expired").u64_val(sched.expired);
    w.key("cancelled").u64_val(sched.cancelled);
    w.key("shed_429").u64_val(state.shed_429.load(Ordering::Relaxed));
    w.key("cache").begin_obj();
    w.key("hits").u64_val(cache.hits);
    w.key("misses").u64_val(cache.misses);
    w.key("evictions").u64_val(cache.evictions);
    w.key("resident_bytes").u64_val(cache_bytes as u64);
    // The configured codec for future installs plus the exact byte
    // ledger per codec actually resident (mixed populations occur
    // after a live cache_quant change until the LRU turns over).
    w.key("quant").str_val(cache_quant);
    w.key("resident_bytes_by_kind").begin_obj();
    w.key("f32").u64_val(by_kind[0] as u64);
    w.key("bf16").u64_val(by_kind[1] as u64);
    w.key("int8").u64_val(by_kind[2] as u64);
    w.end_obj();
    w.end_obj();
    w.key("per_adapter").begin_obj();
    for (name, count) in &sched.per_adapter {
        w.key(name).begin_obj();
        w.key("requests").u64_val(*count);
        w.key("method").str_val(
            method_of.get(name).copied().unwrap_or("unknown"),
        );
        w.end_obj();
    }
    w.end_obj();
    w.key("per_adapter_untracked")
        .u64_val(sched.per_adapter_untracked);
    w.key("methods").begin_obj();
    for (meth, (loaded, requests)) in &methods {
        w.key(meth).begin_obj();
        w.key("adapters").u64_val(*loaded);
        w.key("requests").u64_val(*requests);
        w.end_obj();
    }
    w.end_obj();
    w.key("classes").begin_obj();
    for c in &sched.per_class {
        w.key(&c.class).begin_obj();
        w.key("submitted").u64_val(c.submitted);
        w.key("answered").u64_val(c.answered);
        w.key("p50_us").u64_val(c.p50_us);
        w.key("p95_us").u64_val(c.p95_us);
        w.key("p99_us").u64_val(c.p99_us);
        w.end_obj();
    }
    w.end_obj();
    if let Some(hs) = state.http_stats() {
        w.key("http").begin_obj();
        w.key("accepted").u64_val(hs.accepted.load(Ordering::Relaxed));
        w.key("requests").u64_val(hs.requests.load(Ordering::Relaxed));
        w.key("shed_503").u64_val(hs.shed_503.load(Ordering::Relaxed));
        w.key("bad_requests")
            .u64_val(hs.bad_requests.load(Ordering::Relaxed));
        // Status-class rollup of every response written, including
        // transport-level errors the handlers never see.
        w.key("responses_by_status").begin_obj();
        w.key("2xx")
            .u64_val(hs.responses_2xx.load(Ordering::Relaxed));
        w.key("4xx")
            .u64_val(hs.responses_4xx.load(Ordering::Relaxed));
        w.key("5xx")
            .u64_val(hs.responses_5xx.load(Ordering::Relaxed));
        w.end_obj();
        w.end_obj();
    }
    w.end_obj();
    Response::json(200, w.finish())
}

/// `GET /metrics`: Prometheus text-format (v0.0.4) exposition of
/// every serving counter — scheduler, per-class, per-adapter,
/// per-method, cache (with the per-codec byte ledger), HTTP transport
/// — plus the obs registry's stage histograms and outcome counters.
/// Hand-rolled writer, std only; all series are `cosa_`-prefixed.
fn metrics(state: &GatewayState) -> Response {
    use crate::obs::prom::PromWriter;
    let sched = state.server().scheduler_stats();
    let (cache, cache_bytes, by_kind, adapters, method_of) = {
        let model = state.model();
        let m = model.lock().unwrap_or_else(|p| p.into_inner());
        let method_of: std::collections::BTreeMap<String, &'static str> =
            m.adapters()
                .map(|a| (a.name.to_string(), a.method.name()))
                .collect();
        (
            m.cache_stats(),
            m.cache_bytes(),
            m.cache_bytes_by_kind(),
            m.len(),
            method_of,
        )
    };
    let mut w = PromWriter::new();

    w.header(
        "cosa_adapters_loaded",
        "gauge",
        "Adapters currently resident in the model.",
    );
    w.sample("cosa_adapters_loaded", &[], adapters as u64);
    w.header(
        "cosa_queue_depth",
        "gauge",
        "Requests waiting in the scheduler's class queues.",
    );
    w.sample("cosa_queue_depth", &[], sched.queue_depth);
    w.header(
        "cosa_requests_submitted_total",
        "counter",
        "Requests accepted by the scheduler.",
    );
    w.sample("cosa_requests_submitted_total", &[], sched.submitted);
    w.header(
        "cosa_batches_total",
        "counter",
        "Batches flushed by the scheduler.",
    );
    w.sample("cosa_batches_total", &[], sched.batches);
    w.header(
        "cosa_batched_rows_total",
        "counter",
        "Rows carried by flushed batches.",
    );
    w.sample("cosa_batched_rows_total", &[], sched.batched_rows);
    w.header(
        "cosa_requests_expired_total",
        "counter",
        "Requests that missed their deadline before compute.",
    );
    w.sample("cosa_requests_expired_total", &[], sched.expired);
    w.header(
        "cosa_requests_cancelled_total",
        "counter",
        "Requests cancelled by their caller before compute.",
    );
    w.sample("cosa_requests_cancelled_total", &[], sched.cancelled);
    w.header(
        "cosa_shed_429_total",
        "counter",
        "Forwards shed by gateway admission control.",
    );
    w.sample(
        "cosa_shed_429_total",
        &[],
        state.shed_429.load(Ordering::Relaxed),
    );

    w.header(
        "cosa_class_requests_total",
        "counter",
        "Requests per QoS class by lifecycle point.",
    );
    for c in &sched.per_class {
        w.sample(
            "cosa_class_requests_total",
            &[("class", c.class.as_str()), ("point", "submitted")],
            c.submitted,
        );
        w.sample(
            "cosa_class_requests_total",
            &[("class", c.class.as_str()), ("point", "answered")],
            c.answered,
        );
    }
    w.header(
        "cosa_class_latency_us",
        "histogram",
        "Submit-to-reply service latency by QoS class, log2-us \
         buckets.",
    );
    for c in &sched.per_class {
        if c.hist.count() > 0 {
            w.histogram(
                "cosa_class_latency_us",
                &[("class", c.class.as_str())],
                &c.hist,
            );
        }
    }

    w.header(
        "cosa_adapter_requests_total",
        "counter",
        "Requests submitted per adapter (tracked set).",
    );
    for (name, count) in &sched.per_adapter {
        w.sample(
            "cosa_adapter_requests_total",
            &[("adapter", name.as_str())],
            *count,
        );
    }
    // Per-method rollup, same derivation as /v1/stats.
    let mut methods: std::collections::BTreeMap<&str, (u64, u64)> =
        std::collections::BTreeMap::new();
    for name in method_of.values() {
        methods.entry(name).or_insert((0, 0)).0 += 1;
    }
    for (name, count) in &sched.per_adapter {
        if let Some(meth) = method_of.get(name) {
            methods.entry(meth).or_insert((0, 0)).1 += count;
        }
    }
    w.header(
        "cosa_method_adapters",
        "gauge",
        "Loaded adapters per PEFT method.",
    );
    w.header(
        "cosa_method_requests_total",
        "counter",
        "Requests per PEFT method (loaded adapters only).",
    );
    for (meth, (loaded, requests)) in &methods {
        w.sample("cosa_method_adapters", &[("method", meth)], *loaded);
        w.sample(
            "cosa_method_requests_total",
            &[("method", meth)],
            *requests,
        );
    }

    w.header(
        "cosa_cache_hits_total",
        "counter",
        "Projection-cache hits at plan time.",
    );
    w.sample("cosa_cache_hits_total", &[], cache.hits);
    w.header(
        "cosa_cache_misses_total",
        "counter",
        "Projection-cache misses (regeneration required).",
    );
    w.sample("cosa_cache_misses_total", &[], cache.misses);
    w.header(
        "cosa_cache_evictions_total",
        "counter",
        "Projection-cache LRU evictions.",
    );
    w.sample("cosa_cache_evictions_total", &[], cache.evictions);
    w.header(
        "cosa_cache_resident_bytes",
        "gauge",
        "Projection-cache resident bytes by codec.",
    );
    w.sample(
        "cosa_cache_resident_bytes",
        &[("codec", "f32")],
        by_kind[0] as u64,
    );
    w.sample(
        "cosa_cache_resident_bytes",
        &[("codec", "bf16")],
        by_kind[1] as u64,
    );
    w.sample(
        "cosa_cache_resident_bytes",
        &[("codec", "int8")],
        by_kind[2] as u64,
    );
    w.header(
        "cosa_cache_resident_bytes_total",
        "gauge",
        "Projection-cache resident bytes, all codecs.",
    );
    w.sample("cosa_cache_resident_bytes_total", &[], cache_bytes as u64);

    if let Some(hs) = state.http_stats() {
        w.header(
            "cosa_http_accepted_total",
            "counter",
            "TCP connections accepted.",
        );
        w.sample(
            "cosa_http_accepted_total",
            &[],
            hs.accepted.load(Ordering::Relaxed),
        );
        w.header(
            "cosa_http_requests_total",
            "counter",
            "HTTP requests dispatched to a handler.",
        );
        w.sample(
            "cosa_http_requests_total",
            &[],
            hs.requests.load(Ordering::Relaxed),
        );
        w.header(
            "cosa_http_shed_503_total",
            "counter",
            "Connections shed at the accept queue.",
        );
        w.sample(
            "cosa_http_shed_503_total",
            &[],
            hs.shed_503.load(Ordering::Relaxed),
        );
        w.header(
            "cosa_http_bad_requests_total",
            "counter",
            "Requests rejected by the HTTP parser.",
        );
        w.sample(
            "cosa_http_bad_requests_total",
            &[],
            hs.bad_requests.load(Ordering::Relaxed),
        );
        w.header(
            "cosa_http_responses_total",
            "counter",
            "Responses written, by status class.",
        );
        w.sample(
            "cosa_http_responses_total",
            &[("code", "2xx")],
            hs.responses_2xx.load(Ordering::Relaxed),
        );
        w.sample(
            "cosa_http_responses_total",
            &[("code", "4xx")],
            hs.responses_4xx.load(Ordering::Relaxed),
        );
        w.sample(
            "cosa_http_responses_total",
            &[("code", "5xx")],
            hs.responses_5xx.load(Ordering::Relaxed),
        );
    }

    obs::prom::render_registry(state.obs(), &mut w);
    Response::text(200, "text/plain; version=0.0.4", w.finish())
}

/// `GET /v1/debug/slow`: the slowest traces captured over the sliding
/// window, slowest first.  `stages` maps stage name → µs offset from
/// request start (absent stages never ran on that request's path).
fn debug_slow(state: &GatewayState) -> Response {
    let entries = state.obs().slow_snapshot();
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("window_s").u64_val(obs::SLOW_WINDOW.as_secs());
    w.key("count").u64_val(entries.len() as u64);
    w.key("slow").begin_arr();
    for e in &entries {
        w.begin_obj();
        w.key("id").str_val(&format!("{:016x}", e.id));
        w.key("unix_ms").u64_val(e.unix_ms);
        w.key("total_us").u64_val(e.total_us);
        w.key("class").str_val(e.class);
        w.key("method").str_val(e.method);
        w.key("outcome").str_val(e.outcome);
        w.key("adapter").str_val(&e.adapter);
        w.key("batch_rows").u64_val(u64::from(e.batch_rows));
        w.key("cache_hits").u64_val(u64::from(e.cache_hits));
        w.key("cache_misses").u64_val(u64::from(e.cache_misses));
        w.key("stages").begin_obj();
        for s in Stage::ALL {
            if let Some(us) = e.stages[s.idx()] {
                w.key(s.name()).u64_val(us);
            }
        }
        w.end_obj();
        w.end_obj();
    }
    w.end_arr();
    w.end_obj();
    Response::json(200, w.finish())
}

/// `GET /v1/adapters`: the loaded adapter zoo — per adapter its
/// method kind, per-site dims (`[out, in, core_a, core_b]` in spec
/// order), and the param/byte accounting the methods differ on.
/// Sorted by name (the model's own iteration order).
fn list_adapters(state: &GatewayState) -> Response {
    let mut w = JsonWriter::new();
    w.begin_obj();
    let count = {
        let model = state.model();
        let m = model.lock().unwrap_or_else(|p| p.into_inner());
        w.key("adapters").begin_arr();
        for a in m.adapters() {
            w.begin_obj();
            w.key("name").str_val(&a.name);
            w.key("method").str_val(a.method.name());
            w.key("sites").u64_val(a.sites.len() as u64);
            w.key("param_count").u64_val(a.param_count() as u64);
            w.key("resident_bytes").u64_val(a.resident_bytes() as u64);
            w.key("regen_bytes").u64_val(a.regen_bytes() as u64);
            w.key("site_dims").begin_arr();
            for s in &a.sites {
                let (ca, cb) = s.core_dims();
                w.begin_arr();
                w.u64_val(s.out_dim() as u64);
                w.u64_val(s.in_dim() as u64);
                w.u64_val(ca as u64);
                w.u64_val(cb as u64);
                w.end_arr();
            }
            w.end_arr();
            w.end_obj();
        }
        w.end_arr();
        m.len()
    };
    w.key("count").u64_val(count as u64);
    w.end_obj();
    Response::json(200, w.finish())
}

/// Parsed `/v1/forward` body.
struct ForwardReq {
    adapter: String,
    /// One row per site, spec order (widths validated by the caller).
    rows: Vec<Vec<f32>>,
    deadline_ms: Option<u64>,
    /// QoS class (optional `"class"` key; defaults to interactive).
    class: RequestClass,
}

/// Strict streaming parse — numbers flow straight off the tokenizer
/// into typed row vectors, no DOM in between.
fn parse_forward(
    body: &[u8],
    limits: &crate::wire::json::Limits,
) -> anyhow::Result<ForwardReq> {
    let mut tok = Tokenizer::new(body, limits)?;
    anyhow::ensure!(
        matches!(tok.next()?, Some(Event::ObjBegin)),
        "request body must be a json object"
    );
    let mut adapter: Option<String> = None;
    let mut rows: Option<Vec<Vec<f32>>> = None;
    let mut deadline_ms: Option<u64> = None;
    let mut class = RequestClass::default();
    loop {
        let key: Cow<'_, str> = match tok.next()? {
            Some(Event::Key(k)) => k,
            Some(Event::ObjEnd) => break,
            _ => anyhow::bail!("malformed request object"),
        };
        match key.as_ref() {
            "adapter" => match tok.next()? {
                Some(Event::Str(s)) => adapter = Some(s.into_owned()),
                _ => anyhow::bail!("`adapter` must be a string"),
            },
            "deadline_ms" => match tok.next()? {
                Some(Event::Num(n)) => {
                    anyhow::ensure!(
                        n >= 0.0 && n.fract() == 0.0 && n <= 9.0e15,
                        "`deadline_ms` must be a whole non-negative \
                         number of milliseconds (got {n})"
                    );
                    deadline_ms = Some(n as u64);
                }
                _ => anyhow::bail!("`deadline_ms` must be a number"),
            },
            "class" => match tok.next()? {
                Some(Event::Str(s)) => {
                    class = RequestClass::parse(&s).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown `class` `{s}` (expected \
                             `interactive`, `batch`, or `background`)"
                        )
                    })?;
                }
                _ => anyhow::bail!("`class` must be a string"),
            },
            "rows" => {
                anyhow::ensure!(
                    matches!(tok.next()?, Some(Event::ArrBegin)),
                    "`rows` must be an array of per-site rows"
                );
                let mut rs: Vec<Vec<f32>> = Vec::new();
                loop {
                    match tok.next()? {
                        Some(Event::ArrBegin) => {
                            let mut row: Vec<f32> = Vec::new();
                            loop {
                                match tok.next()? {
                                    Some(Event::Num(n)) => {
                                        let v = n as f32;
                                        anyhow::ensure!(
                                            v.is_finite(),
                                            "row value {n} is outside \
                                             the f32 range"
                                        );
                                        row.push(v);
                                    }
                                    Some(Event::ArrEnd) => break,
                                    _ => anyhow::bail!(
                                        "rows must contain only numbers"
                                    ),
                                }
                            }
                            rs.push(row);
                        }
                        Some(Event::ArrEnd) => break,
                        _ => anyhow::bail!(
                            "`rows` must be an array of arrays of \
                             numbers"
                        ),
                    }
                }
                rows = Some(rs);
            }
            other => anyhow::bail!(
                "unknown field `{other}` (expected `adapter`, `rows`, \
                 `deadline_ms`, `class`)"
            ),
        }
    }
    anyhow::ensure!(tok.next()?.is_none(), "trailing data after body");
    Ok(ForwardReq {
        adapter: adapter
            .ok_or_else(|| anyhow::anyhow!("missing field `adapter`"))?,
        rows: rows
            .ok_or_else(|| anyhow::anyhow!("missing field `rows`"))?,
        deadline_ms,
        class,
    })
}

/// The `x-request-id` echoed on every forward response: the client's
/// value when it is well-formed (visible ASCII, ≤ 64 bytes), else the
/// trace id — so a log line's `req <id>` is always greppable from the
/// caller's side.
fn request_id(req: &Request, trace: Option<&Trace>) -> Option<String> {
    let client = req.header("x-request-id").filter(|v| {
        !v.is_empty()
            && v.len() <= 64
            && v.bytes().all(|b| (0x21..=0x7e).contains(&b))
    });
    match client {
        Some(v) => Some(v.to_string()),
        None => trace.map(Trace::id_hex),
    }
}

/// Terminate a gateway-refused trace (shed / pre-submit error); the
/// scheduler owns termination once the request boards.
fn finish_trace(trace: &mut Option<Trace>, outcome: Outcome) {
    if let Some(t) = trace.take() {
        t.finish(outcome);
    }
}

fn forward(state: &GatewayState, req: &Request) -> Response {
    // The trace is born at the HTTP edge so queueing behind admission
    // control is visible; it rides the scheduler ticket from submit
    // onward (no thread-locals cross the pool).
    let trace = state.obs().begin();
    let rid = request_id(req, trace.as_ref());
    let resp = forward_traced(state, req, trace);
    match rid {
        Some(id) => resp.with_header("x-request-id", &id),
        None => resp,
    }
}

fn forward_traced(
    state: &GatewayState,
    req: &Request,
    mut trace: Option<Trace>,
) -> Response {
    if state.is_draining() {
        finish_trace(&mut trace, Outcome::Shed);
        return Response::error(503, "gateway is draining");
    }
    // Admission control first — shedding must stay cheap under the
    // very overload it exists for, so it runs before body parsing.
    if let Some(why) = state.should_shed() {
        state.shed_429.fetch_add(1, Ordering::Relaxed);
        finish_trace(&mut trace, Outcome::Shed);
        return Response::error(429, &why).with_header(
            "retry-after",
            &state.cfg.retry_after_s.to_string(),
        );
    }
    let fwd = match parse_forward(&req.body, &state.limits) {
        Ok(f) => f,
        Err(e) => {
            finish_trace(&mut trace, Outcome::Errored);
            return Response::error(400, &format!("{e:#}"));
        }
    };
    if let Some(t) = trace.as_mut() {
        t.mark(Stage::Parse);
    }
    // Class-tier admission runs once the class is known: batch and
    // background requests shed at 75% / 50% of the depth watermark.
    if let Some(why) = state.should_shed_class(fwd.class) {
        state.shed_429.fetch_add(1, Ordering::Relaxed);
        finish_trace(&mut trace, Outcome::Shed);
        return Response::error(429, &why).with_header(
            "retry-after",
            &state.cfg.retry_after_s.to_string(),
        );
    }
    if let Some(t) = trace.as_mut() {
        t.mark(Stage::Admission);
    }
    // Validate shape here (400) instead of surfacing the scheduler's
    // submit error as a server-side failure.
    let site_ns = state.site_ns();
    if fwd.rows.len() != site_ns.len() {
        finish_trace(&mut trace, Outcome::Errored);
        return Response::error(
            400,
            &format!(
                "request has {} site rows, model has {} sites",
                fwd.rows.len(),
                site_ns.len()
            ),
        );
    }
    for (i, (row, n)) in fwd.rows.iter().zip(site_ns).enumerate() {
        if row.len() != *n {
            finish_trace(&mut trace, Outcome::Errored);
            return Response::error(
                400,
                &format!(
                    "site {i}: row has {} values, site expects {n}",
                    row.len()
                ),
            );
        }
    }
    // Resolve the adapter at the edge: client-chosen names must not
    // reach the scheduler's per-adapter accounting (or occupy batch
    // plumbing) when they cannot possibly serve.  A concurrent
    // hot-evict can still race this check — the scheduler answers
    // those with the same "unknown adapter" error, mapped 404 below.
    let known = {
        let model = state.model();
        let m = model.lock().unwrap_or_else(|p| p.into_inner());
        m.contains(&fwd.adapter)
    };
    if !known {
        finish_trace(&mut trace, Outcome::Errored);
        return Response::error(
            404,
            &format!("unknown adapter `{}`", fwd.adapter),
        );
    }
    let deadline_ms = match fwd.deadline_ms {
        Some(ms) => ms, // explicit (0 = no deadline)
        None => state.cfg.deadline_ms,
    };
    let ticket = {
        let server = state.server();
        let deadline = (deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(deadline_ms));
        // Ownership of the trace moves to the scheduler here — it
        // stamps the remaining stages and the terminal outcome
        // (including its own submit-time errors).
        let result = server.submit_traced(
            &fwd.adapter,
            fwd.rows,
            fwd.class,
            deadline,
            trace,
        );
        match result {
            Ok(t) => t,
            Err(e) => {
                return Response::error(503, &format!("{e:#}"));
            }
        }
    }; // scheduler read guard drops before the blocking wait
    match ticket.wait() {
        Ok(resp) => {
            let mut w = JsonWriter::new();
            w.begin_obj();
            w.key("adapter").str_val(&fwd.adapter);
            w.key("batch_rows").u64_val(resp.batch_rows as u64);
            w.key("outputs").begin_arr();
            for site in 0..resp.sites() {
                w.begin_arr();
                for &v in resp.site_output(site) {
                    w.f32_val(v);
                }
                w.end_arr();
            }
            w.end_arr();
            w.end_obj();
            Response::json(200, w.finish())
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let status = if msg.contains("unknown adapter") {
                404
            } else if msg.contains("timed out") {
                504
            } else if msg.contains("shut down") {
                503
            } else {
                500
            };
            Response::error(status, &msg)
        }
    }
}

fn load_adapter(
    state: &GatewayState,
    name: &str,
    req: &Request,
) -> Response {
    // Optional body: {"dir": "...", "alpha": 2.0, "method": "cosa"}.
    // The directory falls back to `[serve] preload_dir`; `method`
    // asserts what the checkpoint contains (400 on mismatch, nothing
    // loaded) — a client expecting a CoSA artifact never silently
    // serves a LoRA one.
    let mut dir: Option<String> = None;
    let mut alpha: f32 = GatewayState::DEFAULT_ALPHA;
    let mut want_method: Option<crate::adapters::Method> = None;
    if !req.body.is_empty() {
        let doc = match crate::wire::json::parse_value(
            &req.body,
            &state.limits,
        ) {
            Ok(d) => d,
            Err(e) => return Response::error(400, &format!("{e:#}")),
        };
        let Some(obj) = doc.as_obj() else {
            return Response::error(400, "body must be a json object");
        };
        for (k, v) in obj {
            match k.as_str() {
                "dir" => match v.as_str() {
                    Some(s) => dir = Some(s.to_string()),
                    None => {
                        return Response::error(
                            400,
                            "`dir` must be a string",
                        )
                    }
                },
                "alpha" => match v.as_f64() {
                    Some(a) if (a as f32).is_finite() => alpha = a as f32,
                    _ => {
                        return Response::error(
                            400,
                            "`alpha` must be a finite number",
                        )
                    }
                },
                "method" => match v.as_str().map(|s| {
                    crate::adapters::Method::from_str(s)
                }) {
                    Some(Ok(m)) => want_method = Some(m),
                    Some(Err(e)) => {
                        return Response::error(
                            400,
                            &format!("bad `method`: {e:#}"),
                        )
                    }
                    None => {
                        return Response::error(
                            400,
                            "`method` must be a string",
                        )
                    }
                },
                other => {
                    return Response::error(
                        400,
                        &format!(
                            "unknown field `{other}` (expected `dir`, \
                             `alpha`, `method`)"
                        ),
                    )
                }
            }
        }
    }
    let dir = match dir.or_else(|| state.default_dir()) {
        Some(d) => d,
        None => {
            return Response::error(
                400,
                "no checkpoint directory: pass `dir` in the body or \
                 set [serve] preload_dir",
            )
        }
    };
    let t0 = std::time::Instant::now();
    // Disk I/O happens OUTSIDE the model mutex — a multi-megabyte
    // checkpoint read under the lock would stall every concurrent
    // forward (and every scheduler worker's plan/install) for the
    // duration; only the in-memory insert needs exclusivity.
    let loaded = crate::train::checkpoint::Checkpoint::load_by_name(
        std::path::Path::new(&dir),
        name,
    )
    .and_then(|ck| {
        // The method assertion runs before the insert: a mismatched
        // checkpoint must leave the model untouched.  Site blocks
        // carry the authoritative per-site tag (v3); siteless v1
        // files fall back to the header method.
        let tag = ck
            .sites
            .first()
            .map(|s| s.method.clone())
            .unwrap_or_else(|| ck.method.clone());
        let got = crate::adapters::Method::from_str(&tag)?;
        if let Some(want) = want_method {
            anyhow::ensure!(
                want == got,
                "checkpoint for `{name}` is method `{}`, request \
                 asserted `{}`",
                got.name(),
                want.name()
            );
        }
        let model = state.model();
        let mut m = model.lock().unwrap_or_else(|p| p.into_inner());
        m.load_checkpoint(name, &ck, alpha)
            .map(|()| (m.spec().len(), got.name()))
    });
    match loaded {
        Ok((sites, method)) => {
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            crate::info!(
                "wire: loaded {method} adapter `{name}` from {dir} \
                 ({sites} sites) in {ms:.1} ms"
            );
            let mut w = JsonWriter::new();
            w.begin_obj();
            w.key("adapter").str_val(name);
            w.key("method").str_val(method);
            w.key("sites").u64_val(sites as u64);
            w.key("load_ms").f64_val(ms);
            w.end_obj();
            Response::json(200, w.finish())
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let status =
                if msg.contains("no checkpoint") { 404 } else { 400 };
            Response::error(status, &msg)
        }
    }
}

fn evict_adapter(state: &GatewayState, name: &str) -> Response {
    let evicted = {
        let model = state.model();
        let mut m = model.lock().unwrap_or_else(|p| p.into_inner());
        m.evict(name)
    };
    if evicted {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("adapter").str_val(name);
        w.key("evicted").bool_val(true);
        w.end_obj();
        Response::json(200, w.finish())
    } else {
        Response::error(404, &format!("unknown adapter `{name}`"))
    }
}
