//! Gateway lifecycle: owns the serve scheduler behind the HTTP edge,
//! warm pre-loads checkpoints, sheds load, and drains on shutdown.
//!
//! * **Startup** — `[serve] preload_dir` (env
//!   `COSA_SERVE_PRELOAD_DIR`) names a checkpoint directory; every
//!   loadable checkpoint in it is inserted into the [`AdaptedModel`]
//!   before the scheduler spawns, with per-adapter load times logged
//!   (a cold fleet answering its first Zipf burst from disk is the
//!   failure mode this prevents).
//! * **Admission control** — `POST /v1/forward` is shed with `429 +
//!   Retry-After` when the scheduler queue depth reaches
//!   `[wire] shed_queue_depth`, or when the projection LRU is
//!   evicting faster than `[wire] shed_evictions_per_s` over a
//!   sliding one-second window (a thrashing cache means every queued
//!   request regenerates projections — more queue only multiplies the
//!   regeneration storm).  Either watermark set to 0 disables that
//!   check.  Admission is class-tiered: `"class": "background"`
//!   requests stop boarding at 50% of the depth watermark and
//!   `"batch"` at 75%, so only interactive traffic rides the queue to
//!   the full mark.
//! * **Shutdown** — the gateway first refuses new forwards (503
//!   "draining"), then shuts the scheduler down — which *answers*
//!   every in-flight ticket, so blocked HTTP handlers complete their
//!   responses — and only then joins the HTTP threads.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock, RwLockReadGuard};
use std::time::{Duration, Instant};

use crate::config::{ObsConfig, ServeConfig, WireConfig};
use crate::model::AdaptedModel;
use crate::obs;
use crate::serve::Server;
use crate::train::checkpoint::Checkpoint;
use crate::wire::http::{
    Handler, HttpOptions, HttpServer, HttpStats, Request, Response,
};
use crate::wire::json::Limits;
use crate::{info, warn};

/// Sliding-window tracker for the LRU-thrash watermark.
struct ThrashWindow {
    window_start: Instant,
    evictions_at_start: u64,
}

/// Shared state behind every route handler.
pub struct GatewayState {
    server: RwLock<Server>,
    model: Arc<Mutex<AdaptedModel>>,
    pub cfg: WireConfig,
    pub limits: Limits,
    site_ns: Vec<usize>,
    draining: AtomicBool,
    /// Forwards shed by admission control.
    pub shed_429: AtomicU64,
    http_stats: OnceLock<Arc<HttpStats>>,
    thrash: Mutex<ThrashWindow>,
    /// Default checkpoint directory for `/v1/adapters/{name}/load`
    /// (from `[serve] preload_dir`; empty = none).
    preload_dir: String,
    /// The telemetry registry shared with the scheduler — `/metrics`
    /// and `/v1/debug/slow` read it without touching the server lock.
    obs: Arc<obs::Registry>,
}

impl GatewayState {
    /// Alpha applied to checkpoint loads that do not specify one (the
    /// checkpoint format does not carry alpha; this matches the
    /// serving benches and examples).
    pub const DEFAULT_ALPHA: f32 = 2.0;

    /// Read access to the scheduler (submit paths).  The guard must
    /// drop before blocking on a ticket — shutdown takes the write
    /// side.
    pub fn server(&self) -> RwLockReadGuard<'_, Server> {
        self.server.read().unwrap_or_else(|p| p.into_inner())
    }

    /// The shared adapted model (hot load/evict, cache stats).
    pub fn model(&self) -> Arc<Mutex<AdaptedModel>> {
        self.model.clone()
    }

    /// Per-site input widths, spec order (request validation).
    pub fn site_ns(&self) -> &[usize] {
        &self.site_ns
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    pub fn adapter_count(&self) -> usize {
        self.model
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .len()
    }

    pub fn http_stats(&self) -> Option<&HttpStats> {
        self.http_stats.get().map(|a| a.as_ref())
    }

    /// The telemetry registry (also reachable via the scheduler, but
    /// this accessor skips the server read-lock).
    pub fn obs(&self) -> &Arc<obs::Registry> {
        &self.obs
    }

    pub fn default_dir(&self) -> Option<String> {
        if self.preload_dir.is_empty() {
            None
        } else {
            Some(self.preload_dir.clone())
        }
    }

    /// Admission control: `Some(reason)` when the next forward should
    /// be shed with 429 (see module docs for the two watermarks).
    pub fn should_shed(&self) -> Option<String> {
        let depth_mark = self.cfg.shed_queue_depth as u64;
        if depth_mark > 0 {
            let depth = self.server().queue_depth();
            if depth >= depth_mark {
                return Some(format!(
                    "queue depth {depth} at the shed watermark \
                     {depth_mark}; retry later"
                ));
            }
        }
        if self.cfg.shed_evictions_per_s > 0.0 {
            if let Some(why) = self.thrash_shed() {
                return Some(why);
            }
        }
        None
    }

    /// Class-tier admission: lower QoS classes stop boarding before
    /// the full `[wire] shed_queue_depth` watermark, so a backlog of
    /// batch/background work can never crowd interactive traffic out
    /// of the queue.  Background admits below 50% of the watermark,
    /// batch below 75%, interactive all the way to it (that full mark
    /// is [`should_shed`](Self::should_shed)'s job).  `Some(reason)`
    /// means shed with 429.
    pub fn should_shed_class(
        &self,
        class: crate::serve::RequestClass,
    ) -> Option<String> {
        use crate::serve::RequestClass;
        let full = self.cfg.shed_queue_depth as u64;
        if full == 0 {
            return None; // depth shedding disabled entirely
        }
        let mark = match class {
            // the plain should_shed() check already enforced `full`
            RequestClass::Interactive => return None,
            RequestClass::Batch => (full * 3 / 4).max(1),
            RequestClass::Background => (full / 2).max(1),
        };
        let depth = self.server().queue_depth();
        if depth >= mark {
            return Some(format!(
                "queue depth {depth} at the `{}` admission tier \
                 {mark} (full watermark {full}); retry later",
                class.as_str()
            ));
        }
        None
    }

    /// The eviction-storm watermark half of admission control.
    fn thrash_shed(&self) -> Option<String> {
        let evictions = {
            let m = self.model.lock().unwrap_or_else(|p| p.into_inner());
            m.cache_stats().evictions
        };
        let mut w = self.thrash.lock().unwrap_or_else(|p| p.into_inner());
        let elapsed = w.window_start.elapsed();
        if elapsed >= Duration::from_secs(1) {
            w.window_start = Instant::now();
            w.evictions_at_start = evictions;
            return None; // fresh window: admit and re-measure
        }
        let in_window =
            evictions.saturating_sub(w.evictions_at_start) as f64;
        let budget =
            self.cfg.shed_evictions_per_s * elapsed.as_secs_f64();
        if in_window > budget.max(1.0) {
            return Some(format!(
                "projection cache thrashing: {in_window:.0} \
                 evictions in the last {:.2}s (watermark {}/s); \
                 retry later",
                elapsed.as_secs_f64(),
                self.cfg.shed_evictions_per_s
            ));
        }
        None
    }
}

/// Load every checkpoint in `dir` into `model`, logging per-adapter
/// load times.  Files that are not loadable checkpoints are skipped
/// with a warning (one corrupt file must not keep a whole fleet
/// offline); an unreadable directory is an error.  Returns the loaded
/// adapter names.
pub fn preload_checkpoints(
    model: &mut AdaptedModel,
    dir: &Path,
    alpha: f32,
) -> anyhow::Result<Vec<String>> {
    let entries = std::fs::read_dir(dir).map_err(|e| {
        anyhow::anyhow!("preload dir {}: {e}", dir.display())
    })?;
    let mut names = Vec::new();
    let mut paths: Vec<_> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_file())
        .collect();
    paths.sort(); // deterministic load order
    for path in paths {
        let file = match path.file_name().and_then(|s| s.to_str()) {
            Some(f) => f.to_string(),
            None => continue,
        };
        // `<name>.ckpt` / `<name>.cosa` resolve back to `name`, the
        // same mapping Checkpoint::load_by_name uses.
        let name = file
            .strip_suffix(".ckpt")
            .or_else(|| file.strip_suffix(".cosa"))
            .unwrap_or(&file)
            .to_string();
        let t0 = Instant::now();
        let loaded = Checkpoint::load(&path)
            .and_then(|ck| model.load_checkpoint(&name, &ck, alpha));
        match loaded {
            Ok(()) => {
                info!(
                    "wire: preloaded adapter `{name}` from {} in \
                     {:.1} ms",
                    path.display(),
                    t0.elapsed().as_secs_f64() * 1e3
                );
                names.push(name);
            }
            Err(e) => {
                warn!(
                    "wire: skipping {} during preload: {e:#}",
                    path.display()
                );
            }
        }
    }
    info!(
        "wire: preload complete — {} adapter(s) from {}",
        names.len(),
        dir.display()
    );
    Ok(names)
}

/// The running gateway: HTTP edge + scheduler + shared model.
pub struct Gateway {
    http: Option<HttpServer>,
    /// Bound address, cached at startup so `addr()` stays answerable
    /// (and panic-free) after `shutdown()` takes the server.
    addr: std::net::SocketAddr,
    state: Arc<GatewayState>,
}

impl Gateway {
    /// Preload checkpoints (if `[serve] preload_dir` is set), spawn
    /// the scheduler over `model`, and bind the HTTP edge.  Configs
    /// are taken as-is — apply `env_overridden()` at the call site.
    /// Telemetry runs at `[obs]` defaults (enabled); use
    /// [`start_obs`](Self::start_obs) to pass an explicit config.
    pub fn start(
        model: AdaptedModel,
        serve_cfg: &ServeConfig,
        wire_cfg: &WireConfig,
    ) -> anyhow::Result<Gateway> {
        Self::start_obs(model, serve_cfg, wire_cfg, &ObsConfig::default())
    }

    /// [`start`](Self::start) with an explicit `[obs]` config.  The
    /// registry is built here and threaded two ways: into the
    /// scheduler (which stamps every request's trace) and into
    /// [`GatewayState`] (which serves `/metrics` + `/v1/debug/slow`).
    pub fn start_obs(
        mut model: AdaptedModel,
        serve_cfg: &ServeConfig,
        wire_cfg: &WireConfig,
        obs_cfg: &ObsConfig,
    ) -> anyhow::Result<Gateway> {
        if !serve_cfg.preload_dir.is_empty() {
            preload_checkpoints(
                &mut model,
                Path::new(&serve_cfg.preload_dir),
                GatewayState::DEFAULT_ALPHA,
            )?;
        }
        let site_ns: Vec<usize> =
            model.spec().sites.iter().map(|s| s.shape.n).collect();
        let obs_reg = obs::Registry::new(obs_cfg);
        let server = Server::with_obs(model, serve_cfg, obs_reg.clone());
        let shared_model = server.model();
        let limits = Limits {
            max_bytes: wire_cfg.max_body_bytes,
            ..Limits::default()
        };
        let state = Arc::new(GatewayState {
            server: RwLock::new(server),
            model: shared_model,
            cfg: wire_cfg.clone(),
            limits,
            site_ns,
            draining: AtomicBool::new(false),
            shed_429: AtomicU64::new(0),
            http_stats: OnceLock::new(),
            thrash: Mutex::new(ThrashWindow {
                window_start: Instant::now(),
                evictions_at_start: 0,
            }),
            preload_dir: serve_cfg.preload_dir.clone(),
            obs: obs_reg,
        });
        let handler: Handler = {
            let st = state.clone();
            Arc::new(move |req: &Request| -> Response {
                crate::wire::api::handle(&st, req)
            })
        };
        let opts = HttpOptions {
            workers: wire_cfg.http_workers,
            max_body_bytes: wire_cfg.max_body_bytes,
            read_timeout: Duration::from_millis(wire_cfg.read_timeout_ms),
            write_timeout: Duration::from_millis(
                wire_cfg.write_timeout_ms,
            ),
            keep_alive: wire_cfg.keep_alive,
            max_pending_conns: wire_cfg.max_pending_conns,
        };
        let http =
            HttpServer::bind(&wire_cfg.host, wire_cfg.port, &opts, handler)?;
        let _ = state.http_stats.set(http.stats_arc());
        let addr = http.addr();
        info!("wire: gateway listening on {addr}");
        Ok(Gateway { http: Some(http), addr, state })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<GatewayState> {
        &self.state
    }

    /// The shared adapted model (hot load/evict while serving).
    pub fn model(&self) -> Arc<Mutex<AdaptedModel>> {
        self.state.model()
    }

    /// Drain and stop: refuse new forwards (503), answer every
    /// in-flight ticket via the scheduler's shutdown drain, then join
    /// the HTTP threads.  Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.state.draining.store(true, Ordering::SeqCst);
        {
            // Write access waits for submit-side read guards, which
            // are never held across a blocking ticket wait.
            let mut server = self
                .state
                .server
                .write()
                .unwrap_or_else(|p| p.into_inner());
            server.shutdown();
        }
        if let Some(mut http) = self.http.take() {
            http.shutdown();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::matrix::Matrix;
    use crate::math::rng::Pcg64;
    use crate::model::{ModelSpec, SiteShape};
    use crate::util::json::Json;
    use crate::wire::http::HttpClient;
    use crate::wire::json::parse_value;

    fn test_spec(sites: usize) -> ModelSpec {
        ModelSpec::synthetic(sites, SiteShape { m: 12, n: 10 }, 4, 3)
    }

    fn add_adapter(model: &mut AdaptedModel, name: &str, seed: u64) {
        let mut rng = Pcg64::derive(seed, name);
        let ys: Vec<Matrix> = model
            .spec()
            .sites
            .iter()
            .map(|s| Matrix::gaussian(s.a, s.b, 0.5, &mut rng))
            .collect();
        model.insert_synthetic(name, seed, 2.0, ys).unwrap();
    }

    fn test_wire_cfg() -> WireConfig {
        WireConfig {
            port: 0,
            http_workers: 2,
            max_body_bytes: 1 << 16,
            // Short poll so shutdown never waits out a worker blocked
            // on an idle keep-alive client (tests drop gateways with
            // their clients still connected).
            read_timeout_ms: 250,
            ..WireConfig::default()
        }
    }

    fn test_serve_cfg() -> ServeConfig {
        ServeConfig {
            cache_mb: 4.0,
            max_batch: 4,
            max_wait_us: 200,
            workers: 2,
            ..ServeConfig::default()
        }
    }

    fn forward_body(adapter: &str, xs: &[Vec<f32>]) -> String {
        let mut w = crate::wire::json::JsonWriter::new();
        w.begin_obj();
        w.key("adapter").str_val(adapter);
        w.key("rows").begin_arr();
        for row in xs {
            w.begin_arr();
            for &v in row {
                w.f32_val(v);
            }
            w.end_arr();
        }
        w.end_arr();
        w.end_obj();
        w.finish()
    }

    fn outputs_of(resp_body: &[u8]) -> Vec<Vec<f32>> {
        let doc =
            parse_value(resp_body, &Limits::default()).unwrap();
        doc.get("outputs")
            .expect("outputs field")
            .as_arr()
            .expect("outputs array")
            .iter()
            .map(|row| {
                row.as_arr()
                    .expect("site row")
                    .iter()
                    .map(|v| v.as_f64().expect("number") as f32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn loopback_forward_is_bit_identical_to_inprocess() {
        // The acceptance criterion: JSON-over-HTTP forward on a live
        // gateway == direct AdaptedModel::forward, bit for bit.
        let spec = test_spec(3);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut model, "alpha", 7);
        let mut reference =
            AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut reference, "alpha", 7);

        let mut gw =
            Gateway::start(model, &test_serve_cfg(), &test_wire_cfg())
                .unwrap();
        let mut client = HttpClient::connect(gw.addr()).unwrap();
        let mut rng = Pcg64::new(3);
        for round in 0..3 {
            let xs_mat: Vec<Matrix> = spec
                .sites
                .iter()
                .map(|s| Matrix::gaussian(1, s.shape.n, 1.0, &mut rng))
                .collect();
            let xs: Vec<Vec<f32>> =
                xs_mat.iter().map(|m| m.data.clone()).collect();
            let body = forward_body("alpha", &xs);
            let resp = client
                .request("POST", "/v1/forward", Some(body.as_bytes()))
                .unwrap();
            assert_eq!(
                resp.status,
                200,
                "{}",
                String::from_utf8_lossy(&resp.body)
            );
            let got = outputs_of(&resp.body);
            let want = reference.forward("alpha", &xs_mat).unwrap();
            assert_eq!(got.len(), want.len());
            for (site, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.len(), w.data.len());
                for (p, q) in g.iter().zip(&w.data) {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "round {round} site {site}: wire {p:?} != \
                         in-process {q:?}"
                    );
                }
            }
        }
        gw.shutdown();
        gw.shutdown(); // idempotent
    }

    #[test]
    fn class_field_routes_qos_and_rejects_unknown() {
        let spec = test_spec(1);
        let mut model = AdaptedModel::new(spec, 1 << 20).unwrap();
        add_adapter(&mut model, "alpha", 7);
        let gw =
            Gateway::start(model, &test_serve_cfg(), &test_wire_cfg())
                .unwrap();
        let mut client = HttpClient::connect(gw.addr()).unwrap();
        let row = vec!["0.5"; 10].join(",");
        // one forward per QoS tier: all admitted and answered
        for class in ["interactive", "batch", "background"] {
            let body = format!(
                r#"{{"adapter":"alpha","class":"{class}","rows":[[{row}]]}}"#
            );
            let resp = client
                .request("POST", "/v1/forward", Some(body.as_bytes()))
                .unwrap();
            assert_eq!(
                resp.status,
                200,
                "class {class}: {}",
                String::from_utf8_lossy(&resp.body)
            );
        }
        // unknown class is a 400 before anything reaches the scheduler
        let bad = format!(
            r#"{{"adapter":"alpha","class":"turbo","rows":[[{row}]]}}"#
        );
        let resp = client
            .request("POST", "/v1/forward", Some(bad.as_bytes()))
            .unwrap();
        assert_eq!(resp.status, 400);
        assert!(
            String::from_utf8_lossy(&resp.body).contains("turbo"),
            "error must name the rejected class"
        );
        // per-class accounting shows up in /v1/stats
        let resp = client.request("GET", "/v1/stats", None).unwrap();
        assert_eq!(resp.status, 200);
        let doc = parse_value(&resp.body, &Limits::default()).unwrap();
        let classes = doc.get("classes").expect("classes object");
        for class in ["interactive", "batch", "background"] {
            let c = classes.get(class).expect("per-class entry");
            assert_eq!(
                c.get("submitted").and_then(Json::as_usize),
                Some(1),
                "class {class} must record its one submission"
            );
            assert_eq!(
                c.get("answered").and_then(Json::as_usize),
                Some(1),
                "class {class} must record its one answer"
            );
        }
    }

    /// Spin until `f` holds (worker threads stamp trace outcomes just
    /// after the reply send, so scrapes can race the last stamp).
    fn wait_until(what: &str, mut f: impl FnMut() -> bool) {
        for _ in 0..500 {
            if f() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("timed out waiting for {what}");
    }

    #[test]
    fn metrics_and_debug_slow_expose_the_request_path() {
        let spec = test_spec(1);
        let mut model = AdaptedModel::new(spec, 1 << 20).unwrap();
        add_adapter(&mut model, "alpha", 7);
        let gw =
            Gateway::start(model, &test_serve_cfg(), &test_wire_cfg())
                .unwrap();
        let mut client = HttpClient::connect(gw.addr()).unwrap();
        let row = vec!["0.5"; 10].join(",");
        let body =
            format!(r#"{{"adapter":"alpha","rows":[[{row}]]}}"#);
        for _ in 0..2 {
            let resp = client
                .request("POST", "/v1/forward", Some(body.as_bytes()))
                .unwrap();
            assert_eq!(resp.status, 200);
            // Without a client-supplied id the gateway echoes the
            // trace id: 16 lowercase hex digits.
            let rid = resp
                .headers
                .iter()
                .find(|(k, _)| k == "x-request-id")
                .map(|(_, v)| v.clone())
                .expect("x-request-id on a traced forward");
            assert_eq!(rid.len(), 16, "trace id hex: {rid}");
            assert!(rid.bytes().all(|b| b.is_ascii_hexdigit()));
        }
        // Unknown adapter: refused at the edge, trace ends Errored.
        let ghost =
            format!(r#"{{"adapter":"ghost","rows":[[{row}]]}}"#);
        let resp = client
            .request("POST", "/v1/forward", Some(ghost.as_bytes()))
            .unwrap();
        assert_eq!(resp.status, 404);

        let reg = gw.state().obs().clone();
        use crate::obs::Outcome;
        wait_until("both answers traced", || {
            reg.finished(Outcome::Answered) == 2
        });
        assert_eq!(reg.finished(Outcome::Errored), 1);

        let resp = client.request("GET", "/metrics", None).unwrap();
        assert_eq!(resp.status, 200);
        let text = String::from_utf8(resp.body.clone()).unwrap();
        for needle in [
            "# TYPE cosa_requests_submitted_total counter",
            "cosa_requests_submitted_total 2",
            "cosa_requests_finished_total{outcome=\"answered\"} 2",
            "cosa_requests_finished_total{outcome=\"errored\"} 1",
            "# TYPE cosa_stage_duration_us histogram",
            "cosa_stage_duration_us_bucket{stage=\"gemm\",\
             class=\"interactive\",method=\"cosa\",le=\"+Inf\"} 2",
            "cosa_class_latency_us_bucket{class=\"interactive\",\
             le=\"+Inf\"} 2",
            "cosa_cache_resident_bytes{codec=\"f32\"}",
            "cosa_adapter_requests_total{adapter=\"alpha\"} 2",
            "cosa_method_requests_total{method=\"cosa\"} 2",
            "cosa_http_responses_total{code=\"2xx\"}",
            "cosa_obs_enabled 1",
        ] {
            assert!(
                text.contains(needle),
                "missing `{needle}` in:\n{text}"
            );
        }
        let ct = resp
            .headers
            .iter()
            .find(|(k, _)| k == "content-type")
            .map(|(_, v)| v.clone())
            .unwrap();
        assert!(ct.starts_with("text/plain"), "{ct}");

        // Every finished trace is offered to the slow ring, so even a
        // fast test captures entries — slowest first, stage offsets
        // attached.
        let resp =
            client.request("GET", "/v1/debug/slow", None).unwrap();
        assert_eq!(resp.status, 200);
        let doc = parse_value(&resp.body, &Limits::default()).unwrap();
        let n = doc
            .get("count")
            .and_then(Json::as_usize)
            .expect("count");
        assert!(n >= 3, "expected all three traces captured, got {n}");
        let slow = doc.get("slow").and_then(Json::as_arr).unwrap();
        assert_eq!(slow.len(), n);
        let mut last_total = u64::MAX;
        for e in slow {
            let id = e.get("id").and_then(Json::as_str).unwrap();
            assert_eq!(id.len(), 16);
            let total = e
                .get("total_us")
                .and_then(Json::as_usize)
                .unwrap() as u64;
            assert!(total <= last_total, "entries must sort slowest-first");
            last_total = total;
            let outcome =
                e.get("outcome").and_then(Json::as_str).unwrap();
            if outcome == "answered" {
                let stages = e.get("stages").expect("stages object");
                for s in ["parse", "queue", "gemm", "reply"] {
                    assert!(
                        stages.get(s).is_some(),
                        "answered trace missing stage `{s}`"
                    );
                }
            }
        }
    }

    #[test]
    fn client_request_id_echo_and_shed_outcome_tracing() {
        use std::io::{Read, Write};
        use std::net::TcpStream;

        let spec = test_spec(1);
        let mut model = AdaptedModel::new(spec, 1 << 20).unwrap();
        add_adapter(&mut model, "alpha", 7);
        // Slow flush parks submissions in the queue; watermark 1 makes
        // the next class-tiered admission check shed deterministically.
        let serve_cfg = ServeConfig {
            max_batch: 64,
            max_wait_us: 30_000_000,
            ..test_serve_cfg()
        };
        let wire_cfg =
            WireConfig { shed_queue_depth: 1, ..test_wire_cfg() };
        let mut gw =
            Gateway::start(model, &serve_cfg, &wire_cfg).unwrap();
        let row = vec!["0.5"; 10].join(",");

        // A well-formed client id is echoed verbatim (here on a 404 —
        // the echo must survive error paths too).
        let body =
            format!(r#"{{"adapter":"ghost","rows":[[{row}]]}}"#);
        let mut conn = TcpStream::connect(gw.addr()).unwrap();
        conn.write_all(
            format!(
                "POST /v1/forward HTTP/1.1\r\n\
                 x-request-id: my-id-123\r\n\
                 content-length: {}\r\n\
                 connection: close\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        let mut out = Vec::new();
        conn.read_to_end(&mut out).unwrap();
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("HTTP/1.1 404"), "{text}");
        assert!(
            text.contains("x-request-id: my-id-123"),
            "client id must be echoed: {text}"
        );

        // Park one request, then a background forward sheds with 429
        // and its trace terminates with the Shed outcome.
        let ticket = {
            let server = gw.state().server();
            server
                .submit_classed(
                    "alpha",
                    vec![vec![0.25; 10]],
                    crate::serve::RequestClass::Interactive,
                    None,
                )
                .unwrap()
        };
        wait_until("parked request visible in queue", || {
            gw.state().server().queue_depth() >= 1
        });
        let mut client = HttpClient::connect(gw.addr()).unwrap();
        let bg = format!(
            r#"{{"adapter":"alpha","class":"background","rows":[[{row}]]}}"#
        );
        let resp = client
            .request("POST", "/v1/forward", Some(bg.as_bytes()))
            .unwrap();
        assert_eq!(
            resp.status,
            429,
            "{}",
            String::from_utf8_lossy(&resp.body)
        );
        let reg = gw.state().obs().clone();
        use crate::obs::Outcome;
        assert_eq!(reg.finished(Outcome::Shed), 1);
        drop(client);
        // Shutdown drains the parked request; its trace completes.
        gw.shutdown();
        assert!(ticket.wait().is_ok());
        wait_until("parked request traced", || {
            reg.finished(Outcome::Answered) == 1
        });
    }

    #[test]
    fn malformed_and_mismatched_requests_map_to_4xx() {
        let spec = test_spec(2);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut model, "alpha", 7);
        let gw =
            Gateway::start(model, &test_serve_cfg(), &test_wire_cfg())
                .unwrap();
        let mut client = HttpClient::connect(gw.addr()).unwrap();
        let cases: Vec<(&str, String, u16)> = vec![
            ("garbage json", "{not json".into(), 400),
            ("wrong top-level", "[1,2]".into(), 400),
            (
                "unknown field",
                r#"{"adapter":"alpha","rows":[[0]],"x":1}"#.into(),
                400,
            ),
            ("missing rows", r#"{"adapter":"alpha"}"#.into(), 400),
            (
                "missing adapter",
                r#"{"rows":[[0.0],[0.0]]}"#.into(),
                400,
            ),
            (
                "non-number row value",
                r#"{"adapter":"alpha","rows":[["a"],[0]]}"#.into(),
                400,
            ),
            (
                "row value beyond f32",
                format!(
                    r#"{{"adapter":"alpha","rows":[[1e300{}],[0]]}}"#,
                    ",0".repeat(9)
                ),
                400,
            ),
            (
                "wrong site count",
                forward_body("alpha", &[vec![0.0; 10]]),
                400,
            ),
            (
                "wrong row width",
                forward_body("alpha", &[vec![0.0; 10], vec![0.0; 9]]),
                400,
            ),
            (
                "unknown adapter",
                forward_body("ghost", &[vec![0.0; 10], vec![0.0; 10]]),
                404,
            ),
        ];
        for (what, body, want_status) in cases {
            let resp = client
                .request("POST", "/v1/forward", Some(body.as_bytes()))
                .unwrap();
            assert_eq!(
                resp.status,
                want_status,
                "{what}: {}",
                String::from_utf8_lossy(&resp.body)
            );
        }
        // worker threads survived all of that
        let ok = forward_body("alpha", &[vec![0.1; 10], vec![0.2; 10]]);
        let resp = client
            .request("POST", "/v1/forward", Some(ok.as_bytes()))
            .unwrap();
        assert_eq!(resp.status, 200, "workers must outlive bad requests");
        // unknown route and wrong method
        let resp = client.request("GET", "/v1/nope", None).unwrap();
        assert_eq!(resp.status, 404);
        let resp = client.request("GET", "/v1/forward", None).unwrap();
        assert_eq!(resp.status, 405);
        // oversized body (max_body_bytes = 64 KiB in the test config)
        let huge = [b'x'].repeat((1 << 16) + 1);
        let mut fresh = HttpClient::connect(gw.addr()).unwrap();
        let resp =
            fresh.request("POST", "/v1/forward", Some(&huge)).unwrap();
        assert_eq!(resp.status, 413);
    }

    #[test]
    fn deadlines_expire_as_504_and_queue_watermark_sheds_429() {
        let spec = test_spec(1);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut model, "alpha", 7);
        // max_wait far beyond the test budget: only deadlines can
        // answer queued requests, and queued requests stay queued for
        // the shed check.
        let serve_cfg = ServeConfig {
            max_wait_us: 30_000_000,
            ..test_serve_cfg()
        };
        let wire_cfg = WireConfig {
            shed_queue_depth: 2,
            ..test_wire_cfg()
        };
        let gw = Gateway::start(model, &serve_cfg, &wire_cfg).unwrap();
        let mut client = HttpClient::connect(gw.addr()).unwrap();

        // deadline-carrying request: answered 504 near its deadline
        let body = format!(
            r#"{{"adapter":"alpha","deadline_ms":20,"rows":[[{}]]}}"#,
            ["0.5"; 10].join(",")
        );
        let t0 = Instant::now();
        let resp = client
            .request("POST", "/v1/forward", Some(body.as_bytes()))
            .unwrap();
        assert_eq!(
            resp.status,
            504,
            "{}",
            String::from_utf8_lossy(&resp.body)
        );
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "504 must arrive near the deadline, not at max_wait"
        );

        // fill the queue to the watermark with in-process submits
        // that can never flush (huge max_wait, no deadline) ...
        let t1 = gw
            .state()
            .server()
            .submit("alpha", vec![vec![0.1; 10]])
            .unwrap();
        let t2 = gw
            .state()
            .server()
            .submit("alpha", vec![vec![0.2; 10]])
            .unwrap();
        // ... then the wire sheds
        let resp = client
            .request("POST", "/v1/forward", Some(body.as_bytes()))
            .unwrap();
        assert_eq!(
            resp.status,
            429,
            "{}",
            String::from_utf8_lossy(&resp.body)
        );
        let retry = resp
            .headers
            .iter()
            .find(|(k, _)| k == "retry-after")
            .map(|(_, v)| v.as_str());
        assert_eq!(retry, Some("1"), "429 must carry Retry-After");
        assert!(
            gw.state().shed_429.load(Ordering::Relaxed) >= 1,
            "shed counter must move"
        );
        // shutdown drains the two parked submits (answered, not lost)
        drop(gw);
        assert!(t1.wait().is_ok());
        assert!(t2.wait().is_ok());
    }

    #[test]
    fn load_evict_stats_and_healthz_round_trip() {
        let dir = std::env::temp_dir().join("cosa_wire_load_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let spec = test_spec(2);
        // author a checkpoint for `beta` out-of-band
        let mut author = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut author, "beta", 11);
        let ck = author.checkpoint("beta", "tiny-lm_cosa").unwrap();
        ck.save(&dir.join("beta.ckpt")).unwrap();

        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut model, "alpha", 7);
        let gw =
            Gateway::start(model, &test_serve_cfg(), &test_wire_cfg())
                .unwrap();
        let mut client = HttpClient::connect(gw.addr()).unwrap();

        let resp = client.request("GET", "/healthz", None).unwrap();
        assert_eq!(resp.status, 200);
        let doc = parse_value(&resp.body, &Limits::default()).unwrap();
        assert_eq!(doc.get("status").unwrap().as_str(), Some("ok"));

        // hot-load beta through the wire, then serve it
        let body = format!(r#"{{"dir":"{}"}}"#, dir.display());
        let resp = client
            .request(
                "POST",
                "/v1/adapters/beta/load",
                Some(body.as_bytes()),
            )
            .unwrap();
        assert_eq!(
            resp.status,
            200,
            "{}",
            String::from_utf8_lossy(&resp.body)
        );
        let doc = parse_value(&resp.body, &Limits::default()).unwrap();
        assert_eq!(doc.get("method").unwrap().as_str(), Some("cosa"));
        // a wrong method assertion is refused; the right one reloads
        let body_lora = format!(
            r#"{{"dir":"{}","method":"lora"}}"#,
            dir.display()
        );
        let resp = client
            .request(
                "POST",
                "/v1/adapters/beta/load",
                Some(body_lora.as_bytes()),
            )
            .unwrap();
        assert_eq!(resp.status, 400, "method mismatch must refuse");
        let body_cosa = format!(
            r#"{{"dir":"{}","method":"cosa"}}"#,
            dir.display()
        );
        let resp = client
            .request(
                "POST",
                "/v1/adapters/beta/load",
                Some(body_cosa.as_bytes()),
            )
            .unwrap();
        assert_eq!(resp.status, 200, "matching method must load");
        let fwd = forward_body("beta", &[vec![0.1; 10], vec![0.2; 10]]);
        let resp = client
            .request("POST", "/v1/forward", Some(fwd.as_bytes()))
            .unwrap();
        assert_eq!(resp.status, 200);

        // load of a checkpoint that does not exist
        let resp = client
            .request(
                "POST",
                "/v1/adapters/ghost/load",
                Some(body.as_bytes()),
            )
            .unwrap();
        assert_eq!(resp.status, 404);
        // load with neither body dir nor preload_dir configured
        let resp = client
            .request("POST", "/v1/adapters/beta/load", None)
            .unwrap();
        assert_eq!(resp.status, 400);

        // stats reflect the traffic
        let resp = client.request("GET", "/v1/stats", None).unwrap();
        assert_eq!(resp.status, 200);
        let doc = parse_value(&resp.body, &Limits::default()).unwrap();
        assert_eq!(doc.get("adapters").unwrap().as_usize(), Some(2));
        assert_eq!(doc.get("queue_depth").unwrap().as_usize(), Some(0));
        assert!(
            doc.get("submitted").unwrap().as_usize().unwrap() >= 1
        );
        assert!(doc.get("cache").unwrap().get("hits").is_some());
        // quantized-cache surface: configured codec + per-codec ledger
        let cache = doc.get("cache").unwrap();
        assert_eq!(
            cache.get("quant").and_then(Json::as_str),
            Some("f32"),
            "default cache codec is f32"
        );
        let by_kind = cache.get("resident_bytes_by_kind").unwrap();
        assert_eq!(
            by_kind.get("bf16").and_then(Json::as_usize),
            Some(0),
            "nothing installed under a non-default codec"
        );
        assert_eq!(
            by_kind.get("int8").and_then(Json::as_usize),
            Some(0)
        );
        assert_eq!(
            by_kind.get("f32").and_then(Json::as_usize),
            cache.get("resident_bytes").and_then(Json::as_usize),
            "every resident byte is f32 under the default codec"
        );
        let beta = doc.get("per_adapter").unwrap().get("beta").unwrap();
        assert_eq!(
            beta.get("requests").and_then(Json::as_usize),
            Some(1)
        );
        assert_eq!(beta.get("method").and_then(Json::as_str), Some("cosa"));
        let cosa = doc.get("methods").unwrap().get("cosa").unwrap();
        assert_eq!(cosa.get("adapters").and_then(Json::as_usize), Some(2));
        assert!(
            cosa.get("requests").unwrap().as_usize().unwrap() >= 1,
            "beta's request must roll up under its method"
        );
        assert!(
            doc.get("http").unwrap().get("requests").unwrap().as_usize()
                .unwrap() >= 5
        );

        // the adapter-zoo listing: both adapters, per-site dims
        let resp = client.request("GET", "/v1/adapters", None).unwrap();
        assert_eq!(resp.status, 200);
        let doc = parse_value(&resp.body, &Limits::default()).unwrap();
        assert_eq!(doc.get("count").unwrap().as_usize(), Some(2));
        let listed = doc.get("adapters").unwrap().as_arr().unwrap();
        assert_eq!(listed.len(), 2);
        let alpha = &listed[0]; // BTreeMap order: alpha before beta
        assert_eq!(alpha.get("name").and_then(Json::as_str), Some("alpha"));
        assert_eq!(
            alpha.get("method").and_then(Json::as_str),
            Some("cosa")
        );
        assert_eq!(alpha.get("sites").and_then(Json::as_usize), Some(2));
        assert!(
            alpha.get("param_count").unwrap().as_usize().unwrap() > 0
        );
        let dims = alpha.get("site_dims").unwrap().as_arr().unwrap();
        assert_eq!(dims.len(), 2, "one dim quad per site");
        assert_eq!(dims[0].as_arr().unwrap().len(), 4);

        // evict beta; it stops serving
        let resp = client
            .request("DELETE", "/v1/adapters/beta", None)
            .unwrap();
        assert_eq!(resp.status, 200);
        let resp = client
            .request("POST", "/v1/forward", Some(fwd.as_bytes()))
            .unwrap();
        assert_eq!(resp.status, 404, "evicted adapter must 404");
        let resp = client
            .request("DELETE", "/v1/adapters/beta", None)
            .unwrap();
        assert_eq!(resp.status, 404, "double evict must 404");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn preload_dir_warms_every_checkpoint_at_startup() {
        let dir = std::env::temp_dir().join("cosa_wire_preload_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let spec = test_spec(2);
        let mut author = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        for (name, seed) in [("warm-a", 21u64), ("warm-b", 22u64)] {
            add_adapter(&mut author, name, seed);
            let ck = author.checkpoint(name, "tiny-lm_cosa").unwrap();
            ck.save(&dir.join(format!("{name}.ckpt"))).unwrap();
        }
        // a non-checkpoint file is skipped, not fatal
        std::fs::write(dir.join("notes.txt"), b"not a checkpoint")
            .unwrap();

        let model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        let serve_cfg = ServeConfig {
            preload_dir: dir.display().to_string(),
            ..test_serve_cfg()
        };
        let gw =
            Gateway::start(model, &serve_cfg, &test_wire_cfg()).unwrap();
        assert_eq!(gw.state().adapter_count(), 2, "both warmed");
        let mut client = HttpClient::connect(gw.addr()).unwrap();
        for name in ["warm-a", "warm-b"] {
            let fwd =
                forward_body(name, &[vec![0.1; 10], vec![0.2; 10]]);
            let resp = client
                .request("POST", "/v1/forward", Some(fwd.as_bytes()))
                .unwrap();
            assert_eq!(resp.status, 200, "preloaded `{name}` must serve");
        }
        // a missing preload dir fails startup loudly
        let bad = ServeConfig {
            preload_dir: dir.join("missing").display().to_string(),
            ..test_serve_cfg()
        };
        let fresh = AdaptedModel::new(spec, 1 << 20).unwrap();
        assert!(Gateway::start(fresh, &bad, &test_wire_cfg()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_thrash_watermark_sheds() {
        let spec = test_spec(1);
        // A ~1 KiB budget holds barely three L/R projections (one pair
        // is ~312 bytes at these dims), so round-robining 8 adapters
        // evicts on nearly every forward — a genuine thrash storm.
        let mut model = AdaptedModel::new(spec, 1024).unwrap();
        for i in 0..8u64 {
            add_adapter(&mut model, &format!("c{i}"), 7 + i);
        }
        let wire_cfg = WireConfig {
            shed_queue_depth: 0, // isolate the thrash check
            // effectively "any sustained eviction in the current
            // window sheds" — the window budget floors at 1 eviction
            shed_evictions_per_s: 0.0001,
            ..test_wire_cfg()
        };
        let gw =
            Gateway::start(model, &test_serve_cfg(), &wire_cfg).unwrap();
        assert!(
            gw.state().should_shed().is_none(),
            "an idle gateway with zero evictions must admit"
        );
        let mut client = HttpClient::connect(gw.addr()).unwrap();
        let mut shed = false;
        'out: for round in 0..3 {
            for i in 0..8 {
                let fwd =
                    forward_body(&format!("c{i}"), &[vec![0.1; 10]]);
                let resp = client
                    .request("POST", "/v1/forward", Some(fwd.as_bytes()))
                    .unwrap();
                if resp.status == 429 {
                    shed = true;
                    break 'out;
                }
                assert_eq!(resp.status, 200, "round {round}");
            }
        }
        assert!(
            shed,
            "a 1 KiB cache serving 8 adapters must trip the thrash \
             watermark"
        );
        assert!(gw.state().shed_429.load(Ordering::Relaxed) >= 1);
    }
}
