//! Minimal HTTP/1.1 over `std::net` — the gateway's transport.
//!
//! One accept thread feeds a **bounded** connection queue drained by a
//! fixed worker pool; overflow is answered `503` straight from the
//! accept thread (a full engine must shed at the door, not grow an
//! unbounded backlog).  Workers speak enough HTTP/1.1 for a JSON API:
//! `Content-Length` framing (no chunked bodies — `501`), keep-alive
//! with pipelining-safe carry-over buffers, per-socket read/write
//! timeouts, and bounded heads/bodies (`400`/`413`).  Shutdown stops
//! the listener, drains queued connections, and joins every thread.
//!
//! The module also ships [`HttpClient`], the matching loopback client
//! used by the end-to-end tests and the `serve-bench --wire` driver —
//! the bench must pay the same serialize/parse cost a remote caller
//! would.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Longest accepted request head (request line + headers).
const MAX_HEAD_BYTES: usize = 16 << 10;
/// Most headers accepted per request.
const MAX_HEADERS: usize = 100;
/// Ceiling on HTTP worker threads, however configured (the wire bench
/// checks its client count against this — a keep-alive connection
/// holds its worker, so more closed-loop clients than workers strand).
pub(crate) const MAX_HTTP_WORKERS: usize = 64;
/// Idle keep-alive poll interval when no read timeout is configured —
/// workers must wake to observe shutdown.
const IDLE_POLL: Duration = Duration::from_millis(500);
/// Longest a keep-alive connection may sit idle (no request bytes)
/// before the worker closes it.  Workers are a bounded pool and a
/// connection holds its worker, so unbounded idling would let a
/// handful of idle sockets pin the whole pool forever.
const MAX_KEEP_ALIVE_IDLE: Duration = Duration::from_secs(60);

/// One parsed request.  Header names are lowercased; `path` carries no
/// query string (that lands in `query`, raw).
#[derive(Debug, Default)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// One response.  `headers` carries extras (e.g. `Retry-After`);
/// `Content-Length`, `Content-Type` and `Connection` are written by
/// the server.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    pub content_type: &'static str,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: body.into_bytes(),
            content_type: "application/json",
        }
    }

    /// Plain-text response with an explicit content type — the
    /// `/metrics` exposition uses the Prometheus text-format type.
    pub fn text(
        status: u16,
        content_type: &'static str,
        body: String,
    ) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: body.into_bytes(),
            content_type,
        }
    }

    /// The uniform error shape: `{"error": "..."}` with the mapped
    /// status.
    pub fn error(status: u16, msg: &str) -> Response {
        let mut w = super::json::JsonWriter::new();
        w.begin_obj();
        w.key("error").str_val(msg);
        w.end_obj();
        Response::json(status, w.finish())
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }
}

pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        204 => "No Content",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// Transport knobs (the gateway maps `config::WireConfig` onto this).
#[derive(Clone, Debug)]
pub struct HttpOptions {
    /// Worker threads; 0 = auto (available parallelism, capped at 8).
    pub workers: usize,
    pub max_body_bytes: usize,
    /// 0 = no stall timeout (idle keep-alive waits poll regardless).
    pub read_timeout: Duration,
    /// 0 = no write timeout.
    pub write_timeout: Duration,
    pub keep_alive: bool,
    /// Bounded accept-queue capacity; overflow is shed with 503.
    pub max_pending_conns: usize,
}

impl Default for HttpOptions {
    fn default() -> Self {
        HttpOptions {
            workers: 0,
            max_body_bytes: 8 << 20,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            keep_alive: true,
            max_pending_conns: 64,
        }
    }
}

/// Transport counters (surfaced through `/v1/stats` and `/metrics`).
#[derive(Default)]
pub struct HttpStats {
    pub accepted: AtomicU64,
    pub shed_503: AtomicU64,
    pub requests: AtomicU64,
    pub bad_requests: AtomicU64,
    /// Status-class rollup of every response actually written — the
    /// handler's answers plus transport-level errors (400/408/413,
    /// accept-queue 503s).  Informational/3xx statuses never occur
    /// here, so three classes cover the space.
    pub responses_2xx: AtomicU64,
    pub responses_4xx: AtomicU64,
    pub responses_5xx: AtomicU64,
}

impl HttpStats {
    /// Bump the status-class rollup for one written response.
    pub fn record_status(&self, status: u16) {
        let c = match status / 100 {
            2 => &self.responses_2xx,
            4 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }
}

struct ConnQueue {
    q: Mutex<(VecDeque<TcpStream>, bool)>, // (queue, closed)
    cv: Condvar,
    cap: usize,
}

impl ConnQueue {
    /// Enqueue, or hand the connection back on overflow/close so the
    /// caller can answer 503 on it.
    fn push(&self, s: TcpStream) -> Result<(), TcpStream> {
        let mut g = self.q.lock().unwrap_or_else(|p| p.into_inner());
        if g.1 || g.0.len() >= self.cap {
            return Err(s);
        }
        g.0.push_back(s);
        self.cv.notify_one();
        Ok(())
    }

    fn pop(&self) -> Option<TcpStream> {
        let mut g = self.q.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(s) = g.0.pop_front() {
                return Some(s);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn close(&self) {
        let mut g = self.q.lock().unwrap_or_else(|p| p.into_inner());
        g.1 = true;
        self.cv.notify_all();
    }
}

/// The bounded accept/worker HTTP server (see module docs).
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<ConnQueue>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<HttpStats>,
}

fn resolve_workers(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .clamp(2, 8)
    } else {
        requested.min(MAX_HTTP_WORKERS)
    }
}

impl HttpServer {
    /// Bind `host:port` (port 0 = ephemeral; `addr()` reports the
    /// outcome) and start the accept thread + worker pool.
    pub fn bind(
        host: &str,
        port: u16,
        opts: &HttpOptions,
        handler: Handler,
    ) -> anyhow::Result<HttpServer> {
        let listener = TcpListener::bind((host, port)).map_err(|e| {
            anyhow::anyhow!("cannot bind {host}:{port}: {e}")
        })?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(HttpStats::default());
        let queue = Arc::new(ConnQueue {
            q: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
            cap: opts.max_pending_conns.max(1),
        });

        let worker_count = resolve_workers(opts.workers);
        let mut workers = Vec::with_capacity(worker_count);
        for _ in 0..worker_count {
            let q = queue.clone();
            let h = handler.clone();
            let o = opts.clone();
            let st = stop.clone();
            let hs = stats.clone();
            workers.push(std::thread::spawn(move || {
                while let Some(conn) = q.pop() {
                    serve_conn(conn, &h, &o, &st, &hs);
                }
            }));
        }

        let accept = {
            let q = queue.clone();
            let st = stop.clone();
            let hs = stats.clone();
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if st.load(Ordering::Relaxed) {
                        break;
                    }
                    let conn = match conn {
                        Ok(c) => c,
                        Err(_) => continue,
                    };
                    hs.accepted.fetch_add(1, Ordering::Relaxed);
                    let _ = conn.set_nodelay(true);
                    if let Err(mut conn) = q.push(conn) {
                        // Shed at the door: the queue bound is the
                        // backpressure contract — answer 503 from the
                        // accept thread without occupying a worker.
                        hs.shed_503.fetch_add(1, Ordering::Relaxed);
                        hs.record_status(503);
                        let _ = conn.set_write_timeout(Some(
                            Duration::from_millis(500),
                        ));
                        let _ = write_response(
                            &mut conn,
                            &Response::error(
                                503,
                                "connection queue is full",
                            ),
                            false,
                        );
                    }
                }
            })
        };
        Ok(HttpServer {
            addr,
            stop,
            queue,
            accept: Some(accept),
            workers,
            stats,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &HttpStats {
        &self.stats
    }

    /// Shared handle to the counters (outlives the server's borrow —
    /// the gateway stores it next to its own state).
    pub fn stats_arc(&self) -> Arc<HttpStats> {
        self.stats.clone()
    }

    /// Stop accepting, drain queued connections, join every thread.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect_timeout(
            &self.addr,
            Duration::from_millis(200),
        );
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Where `\r\n\r\n` ends, if present.
fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Briefly drain unread request bytes before an early close so the
/// peer receives the error response instead of a reset (closing a
/// socket with unread data RSTs, which can discard the in-flight
/// answer).  Bounded in both bytes and time.
fn drain_before_close(conn: &mut TcpStream) {
    let _ = conn.set_read_timeout(Some(Duration::from_millis(100)));
    let mut sink = [0u8; 8192];
    let mut drained = 0usize;
    while drained < (1 << 20) {
        match conn.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(n) => drained += n,
        }
    }
}

fn serve_conn(
    conn: TcpStream,
    handler: &Handler,
    opts: &HttpOptions,
    stop: &AtomicBool,
    stats: &HttpStats,
) {
    let mut conn = conn;
    // A real timeout is always installed so workers wake to observe
    // shutdown; with no configured timeout the poll never closes a
    // stalled request, it only re-checks the flag.
    let stall_closes = !opts.read_timeout.is_zero();
    let poll = if stall_closes { opts.read_timeout } else { IDLE_POLL };
    let _ = conn.set_read_timeout(Some(poll));
    if !opts.write_timeout.is_zero() {
        let _ = conn.set_write_timeout(Some(opts.write_timeout));
    }
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 8192];
    loop {
        // -- read one request head (keep-alive carry-over aware) --
        // `wait_start` anchors two budgets: a request, once its first
        // byte arrives, must complete within `read_timeout` *total*
        // (a per-read clock would let a trickle-feeding client hold
        // the worker forever — one byte per poll resets nothing
        // here), and an idle connection is closed after
        // MAX_KEEP_ALIVE_IDLE.
        let mut wait_start = Instant::now();
        let mut started = !buf.is_empty(); // pipelined carry-over
        let head_len = loop {
            if let Some(end) = head_end(&buf) {
                break end;
            }
            if buf.len() > MAX_HEAD_BYTES {
                stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                stats.record_status(400);
                let _ = write_response(
                    &mut conn,
                    &Response::error(400, "request head too large"),
                    false,
                );
                drain_before_close(&mut conn);
                return;
            }
            if started
                && stall_closes
                && wait_start.elapsed() >= opts.read_timeout
            {
                // Total-budget stall: answer and give up.
                stats.record_status(408);
                let _ = write_response(
                    &mut conn,
                    &Response::error(408, "request timed out"),
                    false,
                );
                drain_before_close(&mut conn);
                return;
            }
            match conn.read(&mut chunk) {
                Ok(0) => return, // peer closed
                Ok(n) => {
                    if !started {
                        started = true;
                        wait_start = Instant::now();
                    }
                    buf.extend_from_slice(&chunk[..n]);
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if stop.load(Ordering::Relaxed) {
                        return; // shutting down; drop idle connection
                    }
                    if !started
                        && wait_start.elapsed() >= MAX_KEEP_ALIVE_IDLE
                    {
                        return; // idle too long; free the worker
                    }
                }
                Err(_) => return,
            }
        };

        // -- parse the head --
        let (mut req, content_length) =
            match parse_head(&buf[..head_len]) {
                Ok(ok) => ok,
                Err(msg) => {
                    stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                    stats.record_status(400);
                    let _ = write_response(
                        &mut conn,
                        &Response::error(400, &msg),
                        false,
                    );
                    drain_before_close(&mut conn);
                    return;
                }
            };
        if req.header("transfer-encoding").is_some() {
            stats.bad_requests.fetch_add(1, Ordering::Relaxed);
            stats.record_status(501);
            let _ = write_response(
                &mut conn,
                &Response::error(
                    501,
                    "chunked transfer encoding is not supported; \
                     send Content-Length",
                ),
                false,
            );
            drain_before_close(&mut conn);
            return;
        }
        if content_length > opts.max_body_bytes {
            // Answer without reading the remainder — the connection
            // cannot be reused after an unread body.
            stats.record_status(413);
            let _ = write_response(
                &mut conn,
                &Response::error(
                    413,
                    &format!(
                        "body of {content_length} bytes exceeds the \
                         {}-byte limit",
                        opts.max_body_bytes
                    ),
                ),
                false,
            );
            drain_before_close(&mut conn);
            return;
        }

        // -- read the body (some of it may already be buffered) --
        let total = head_len + content_length;
        while buf.len() < total {
            if stall_closes && wait_start.elapsed() >= opts.read_timeout {
                // Same total budget as the head: trickled bodies must
                // not hold the worker past the request's clock.
                stats.record_status(408);
                let _ = write_response(
                    &mut conn,
                    &Response::error(408, "request timed out"),
                    false,
                );
                drain_before_close(&mut conn);
                return;
            }
            match conn.read(&mut chunk) {
                Ok(0) => return, // truncated body; nothing to answer
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    if stall_closes {
                        stats.record_status(408);
                        let _ = write_response(
                            &mut conn,
                            &Response::error(408, "request timed out"),
                            false,
                        );
                        drain_before_close(&mut conn);
                        return;
                    }
                }
                Err(_) => return,
            }
        }
        req.body = buf[head_len..total].to_vec();
        // Pipelining-safe carry-over for the next request.
        buf.drain(..total);

        // -- dispatch --
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let resp = handler(&req);
        stats.record_status(resp.status);
        let client_close = req
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"));
        let keep = opts.keep_alive
            && !client_close
            && !stop.load(Ordering::Relaxed);
        match write_response(&mut conn, &resp, keep) {
            Ok(()) if keep => continue,
            _ => return,
        }
    }
}

/// Parse the request line + headers; returns the request (body empty)
/// and the declared content length.
fn parse_head(head: &[u8]) -> Result<(Request, usize), String> {
    let text = std::str::from_utf8(head)
        .map_err(|_| "request head is not valid utf-8".to_string())?;
    let mut lines = text.split("\r\n");
    let line = lines.next().unwrap_or("");
    let mut parts = line.split(' ');
    let (method, target, version) = (
        parts.next().unwrap_or(""),
        parts.next().unwrap_or(""),
        parts.next().unwrap_or(""),
    );
    if method.is_empty()
        || target.is_empty()
        || parts.next().is_some()
        || !matches!(version, "HTTP/1.1" | "HTTP/1.0")
    {
        return Err(format!("malformed request line `{line}`"));
    }
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(format!("malformed method `{method}`"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    if !path.starts_with('/') {
        return Err(format!("target `{target}` is not an absolute path"));
    }
    let mut req = Request {
        method: method.to_string(),
        path: path.to_string(),
        query: query.to_string(),
        ..Request::default()
    };
    let mut content_length: Option<usize> = None;
    for line in lines {
        if line.is_empty() {
            continue; // the blank line terminating the head
        }
        if req.headers.len() >= MAX_HEADERS {
            return Err("too many headers".to_string());
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| format!("malformed header `{line}`"))?;
        // Whitespace or controls inside a header name are the classic
        // proxy-disagreement smuggling shape (`content-length\t:`) —
        // reject, don't reinterpret.
        if name.is_empty()
            || name
                .bytes()
                .any(|b| b.is_ascii_whitespace() || b.is_ascii_control())
        {
            return Err(format!("malformed header name `{name}`"));
        }
        let name = name.to_ascii_lowercase();
        let value = value.trim().to_string();
        if name == "content-length" {
            // Duplicate Content-Length headers are a request-smuggling
            // vector behind a framing-disagreeing proxy (RFC 7230
            // §3.3.2 requires rejecting conflicts) — refuse them
            // outright rather than pick one.  The value must be
            // 1*DIGIT exactly: `+5`/`0x5` forms parse differently
            // across implementations, same vector.
            if content_length.is_some() {
                return Err("duplicate content-length header".to_string());
            }
            if value.is_empty()
                || !value.bytes().all(|b| b.is_ascii_digit())
            {
                return Err(format!("bad content-length `{value}`"));
            }
            content_length = Some(value.parse::<usize>().map_err(
                |_| format!("bad content-length `{value}`"),
            )?);
        }
        req.headers.push((name, value));
    }
    Ok((req, content_length.unwrap_or(0)))
}

fn write_response(
    conn: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut out = Vec::with_capacity(resp.body.len() + 256);
    out.extend_from_slice(
        format!(
            "HTTP/1.1 {} {}\r\n",
            resp.status,
            reason(resp.status)
        )
        .as_bytes(),
    );
    out.extend_from_slice(
        format!("content-type: {}\r\n", resp.content_type).as_bytes(),
    );
    out.extend_from_slice(
        format!("content-length: {}\r\n", resp.body.len()).as_bytes(),
    );
    for (k, v) in &resp.headers {
        out.extend_from_slice(format!("{k}: {v}\r\n").as_bytes());
    }
    out.extend_from_slice(if keep_alive {
        b"connection: keep-alive\r\n"
    } else {
        b"connection: close\r\n"
    });
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(&resp.body);
    conn.write_all(&out)?;
    conn.flush()
}

/// Blocking keep-alive client for the loopback tests and the wire
/// bench.  Speaks exactly the server's subset: `Content-Length`
/// framing, no chunked bodies.
pub struct HttpClient {
    conn: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> anyhow::Result<HttpClient> {
        let conn = TcpStream::connect_timeout(
            &addr,
            Duration::from_secs(5),
        )?;
        let _ = conn.set_nodelay(true);
        conn.set_read_timeout(Some(Duration::from_secs(30)))?;
        conn.set_write_timeout(Some(Duration::from_secs(30)))?;
        Ok(HttpClient { conn, buf: Vec::new() })
    }

    /// One request/response exchange on the persistent connection.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> anyhow::Result<Response> {
        let mut out = Vec::with_capacity(
            body.map_or(0, <[u8]>::len) + 128,
        );
        out.extend_from_slice(
            format!("{method} {path} HTTP/1.1\r\n").as_bytes(),
        );
        out.extend_from_slice(b"host: localhost\r\n");
        if let Some(b) = body {
            out.extend_from_slice(
                b"content-type: application/json\r\n",
            );
            out.extend_from_slice(
                format!("content-length: {}\r\n", b.len()).as_bytes(),
            );
        }
        out.extend_from_slice(b"\r\n");
        if let Some(b) = body {
            out.extend_from_slice(b);
        }
        self.conn.write_all(&out)?;
        self.conn.flush()?;
        self.read_response()
    }

    fn read_response(&mut self) -> anyhow::Result<Response> {
        let mut chunk = [0u8; 8192];
        let head_len = loop {
            if let Some(end) = head_end(&self.buf) {
                break end;
            }
            anyhow::ensure!(
                self.buf.len() <= MAX_HEAD_BYTES,
                "response head too large"
            );
            let n = self.conn.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "server closed mid-response");
            self.buf.extend_from_slice(&chunk[..n]);
        };
        let text = std::str::from_utf8(&self.buf[..head_len])?;
        let mut lines = text.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                anyhow::anyhow!("bad status line `{status_line}`")
            })?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        for line in lines {
            if line.is_empty() {
                continue;
            }
            if let Some((k, v)) = line.split_once(':') {
                let k = k.to_ascii_lowercase();
                let v = v.trim().to_string();
                if k == "content-length" {
                    content_length = v.parse()?;
                }
                headers.push((k, v));
            }
        }
        let total = head_len + content_length;
        while self.buf.len() < total {
            let n = self.conn.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "server closed mid-body");
            self.buf.extend_from_slice(&chunk[..n]);
        }
        let body = self.buf[head_len..total].to_vec();
        self.buf.drain(..total);
        Ok(Response {
            status,
            headers,
            body,
            content_type: "application/json",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server(opts: HttpOptions) -> HttpServer {
        let handler: Handler = Arc::new(|req: &Request| {
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/ping") => Response::json(200, "\"pong\"".into()),
                ("POST", "/echo") => Response {
                    status: 200,
                    headers: Vec::new(),
                    body: req.body.clone(),
                    content_type: "application/json",
                },
                _ => Response::error(404, "no such route"),
            }
        });
        HttpServer::bind("127.0.0.1", 0, &opts, handler).unwrap()
    }

    #[test]
    fn keep_alive_serves_multiple_requests_per_connection() {
        let mut server = echo_server(HttpOptions::default());
        let mut client = HttpClient::connect(server.addr()).unwrap();
        for i in 0..3 {
            let body = format!("[{i},{i}]");
            let resp = client
                .request("POST", "/echo", Some(body.as_bytes()))
                .unwrap();
            assert_eq!(resp.status, 200);
            assert_eq!(resp.body, body.as_bytes());
        }
        let resp = client.request("GET", "/ping", None).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(
            server.stats().requests.load(Ordering::Relaxed),
            4,
            "all four requests must ride one accepted connection"
        );
        assert_eq!(server.stats().accepted.load(Ordering::Relaxed), 1);
        let resp = client.request("GET", "/nope", None).unwrap();
        assert_eq!(resp.status, 404);
        drop(client); // EOF frees the worker before the join below
        server.shutdown();
        server.shutdown(); // idempotent
    }

    #[test]
    fn status_rollup_counts_every_written_response() {
        let server = echo_server(HttpOptions::default());
        let mut client = HttpClient::connect(server.addr()).unwrap();
        let ok = client.request("GET", "/ping", None).unwrap();
        assert_eq!(ok.status, 200);
        let missing = client.request("GET", "/nope", None).unwrap();
        assert_eq!(missing.status, 404);
        let s = server.stats();
        assert_eq!(s.responses_2xx.load(Ordering::Relaxed), 1);
        assert_eq!(s.responses_4xx.load(Ordering::Relaxed), 1);
        assert_eq!(s.responses_5xx.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn oversized_bodies_get_413_without_reading_them() {
        let opts =
            HttpOptions { max_body_bytes: 64, ..HttpOptions::default() };
        let server = echo_server(opts);
        let mut client = HttpClient::connect(server.addr()).unwrap();
        let oversize = [b'x'].repeat(65);
        let resp = client
            .request("POST", "/echo", Some(&oversize))
            .unwrap();
        assert_eq!(resp.status, 413);
        let small = client.request("POST", "/echo", Some(b"ok"));
        assert!(
            small.is_err(),
            "413 must close the connection (body was never read)"
        );
    }

    #[test]
    fn malformed_heads_get_400() {
        let server = echo_server(HttpOptions::default());
        for bad in [
            "GARBAGE\r\n\r\n",
            "GET /ping HTTP/2.0\r\n\r\n",
            "GET /ping HTTP/1.1 extra\r\n\r\n",
            "get /ping HTTP/1.1\r\n\r\n",
            "GET ping HTTP/1.1\r\n\r\n",
            "GET /ping HTTP/1.1\r\nbad header\r\n\r\n",
            "POST /echo HTTP/1.1\r\ncontent-length: -1\r\n\r\n",
            "POST /echo HTTP/1.1\r\ncontent-length: +2\r\n\r\nok",
            "POST /echo HTTP/1.1\r\ncontent-length\t: 2\r\n\r\nok",
            "POST /echo HTTP/1.1\r\ncontent-length: 2\r\n\
             content-length: 0\r\n\r\nok",
        ] {
            let mut conn =
                TcpStream::connect(server.addr()).unwrap();
            conn.write_all(bad.as_bytes()).unwrap();
            let mut out = Vec::new();
            conn.read_to_end(&mut out).unwrap();
            let text = String::from_utf8_lossy(&out);
            assert!(
                text.starts_with("HTTP/1.1 400"),
                "`{bad:?}` got: {text}"
            );
        }
        assert!(
            server.stats().bad_requests.load(Ordering::Relaxed) >= 7
        );
    }

    #[test]
    fn chunked_bodies_are_501() {
        let server = echo_server(HttpOptions::default());
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(
            b"POST /echo HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n",
        )
        .unwrap();
        let mut out = Vec::new();
        conn.read_to_end(&mut out).unwrap();
        assert!(String::from_utf8_lossy(&out)
            .starts_with("HTTP/1.1 501"));
    }

    #[test]
    fn connection_close_is_honored() {
        let server = echo_server(HttpOptions::default());
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(
            b"GET /ping HTTP/1.1\r\nconnection: close\r\n\r\n",
        )
        .unwrap();
        let mut out = Vec::new();
        conn.read_to_end(&mut out).unwrap(); // EOF: server closed
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("HTTP/1.1 200"), "{text}");
        assert!(text.contains("connection: close"), "{text}");
    }

    #[test]
    fn pipelined_requests_are_served_in_order() {
        let server = echo_server(HttpOptions::default());
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(
            b"POST /echo HTTP/1.1\r\ncontent-length: 3\r\n\r\n\
              [1]POST /echo HTTP/1.1\r\ncontent-length: 3\r\n\r\n[2]",
        )
        .unwrap();
        let mut got = Vec::new();
        let mut chunk = [0u8; 4096];
        while !String::from_utf8_lossy(&got).contains("[2]") {
            let n = conn.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed before both answers");
            got.extend_from_slice(&chunk[..n]);
        }
        let text = String::from_utf8_lossy(&got);
        let first = text.find("[1]").expect("first answer");
        let second = text.find("[2]").expect("second answer");
        assert!(first < second, "answers out of order: {text}");
        drop(conn); // EOF frees the worker before the drop-join
    }

    #[test]
    fn shutdown_with_idle_keepalive_connection_joins() {
        let opts = HttpOptions {
            read_timeout: Duration::ZERO, // poll path must still wake
            ..HttpOptions::default()
        };
        let mut server = echo_server(opts);
        let mut client = HttpClient::connect(server.addr()).unwrap();
        let resp = client.request("GET", "/ping", None).unwrap();
        assert_eq!(resp.status, 200);
        // client now idles; shutdown must not hang on the worker
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "shutdown hung on an idle keep-alive connection"
        );
    }
}
