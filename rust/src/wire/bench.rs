// lint: allow-file(panic) — bench driver, not a request path: a panic aborts the measurement run loudly instead of producing a silently wrong report.
//! Loopback wire benchmark — the `serving_wire` report section behind
//! `serve-bench --wire` and `benches/serve_bench.rs` scenario 4.
//!
//! Two passes over the same Zipf-skewed single-site workload, built
//! from bit-identical synthetic registries:
//!
//! 1. **in-process** — `clients` closed-loop submitter threads drive
//!    the batched [`Server`](crate::serve::Server) directly
//!    (submit → wait per request).  This is the ceiling: the same
//!    engine at the same concurrency, minus the wire.
//! 2. **wire** — a [`Gateway`] on a loopback ephemeral port, the same
//!    thread count each owning one keep-alive [`HttpClient`]
//!    connection, every request paying the full serialize → HTTP →
//!    parse → forward → serialize → HTTP round trip.
//!
//! `wire_vs_inprocess` (wire throughput / in-process throughput) is
//! the machine-independent CI gate: the HTTP + JSON edge must keep at
//! least half the engine's closed-loop throughput (floors live in
//! `BENCH_baseline.json`, gated by `tools/bench_regression.py`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::config::{ServeConfig, WireConfig};
use crate::model::SiteShape;
use crate::serve::bench::{percentile, synthetic_registry, Zipf, X_POOL};
use crate::serve::Server;
use crate::util::json::{obj, Json};
use crate::wire::gateway::Gateway;
use crate::wire::http::HttpClient;
use crate::wire::json::JsonWriter;

/// Wire workload description (always firehose / closed-loop — the
/// wire scenario measures edge overhead, not pacing).
#[derive(Clone, Debug)]
pub struct WireBenchOpts {
    pub adapters: usize,
    pub requests: usize,
    /// Concurrent keep-alive connections (and in-process submitter
    /// threads — both passes run at this concurrency).
    pub clients: usize,
    pub zipf: f64,
    pub site: SiteShape,
    pub core_a: usize,
    pub core_b: usize,
    pub seed: u64,
    pub serve: ServeConfig,
    pub wire: WireConfig,
}

impl Default for WireBenchOpts {
    fn default() -> Self {
        WireBenchOpts {
            adapters: 64,
            requests: 2048,
            clients: 8,
            zipf: 1.1,
            site: SiteShape { m: 256, n: 256 },
            core_a: 64,
            core_b: 48,
            seed: 11,
            serve: ServeConfig::default(),
            wire: WireConfig {
                port: 0, // never collide with a real deployment
                ..WireConfig::default()
            },
        }
    }
}

/// One measured wire scenario (a `serving_wire` bench row).
#[derive(Clone, Debug)]
pub struct WireBenchReport {
    pub opts: WireBenchOpts,
    pub workers: usize,
    pub inproc_wall_s: f64,
    pub wire_wall_s: f64,
    pub inproc_throughput_rps: f64,
    pub throughput_rps: f64,
    /// The machine-independent CI gate: wire / in-process throughput.
    pub wire_vs_inprocess: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_batch_rows: f64,
    /// Non-200 responses seen by the bench clients (must be 0).
    pub errors: u64,
    /// 429 sheds observed (admission control must stay quiet under
    /// the default watermarks).
    pub shed_429: u64,
}

impl WireBenchReport {
    pub fn to_json(&self) -> Json {
        let o = &self.opts;
        obj(vec![
            ("adapters", o.adapters.into()),
            ("requests", o.requests.into()),
            ("clients", o.clients.into()),
            ("zipf", o.zipf.into()),
            ("rate_rps", Json::Num(0.0)),
            ("site_m", o.site.m.into()),
            ("site_n", o.site.n.into()),
            ("core_a", o.core_a.into()),
            ("core_b", o.core_b.into()),
            ("max_batch", o.serve.max_batch.into()),
            ("max_wait_us", (o.serve.max_wait_us as usize).into()),
            ("workers", self.workers.into()),
            ("inproc_wall_s", self.inproc_wall_s.into()),
            ("wire_wall_s", self.wire_wall_s.into()),
            (
                "inproc_throughput_rps",
                self.inproc_throughput_rps.into(),
            ),
            ("throughput_rps", self.throughput_rps.into()),
            ("wire_vs_inprocess", self.wire_vs_inprocess.into()),
            ("mean_ms", self.mean_ms.into()),
            ("p50_ms", self.p50_ms.into()),
            ("p95_ms", self.p95_ms.into()),
            ("p99_ms", self.p99_ms.into()),
            ("mean_batch_rows", self.mean_batch_rows.into()),
            ("errors", (self.errors as usize).into()),
            ("shed_429", (self.shed_429 as usize).into()),
        ])
    }

    pub fn print(&self) {
        let o = &self.opts;
        println!(
            "serve-wire[{} adapters, zipf {:.2}, {} reqs, {} clients, \
             batch<= {}, {} workers]",
            o.adapters, o.zipf, o.requests, o.clients,
            o.serve.max_batch, self.workers
        );
        println!(
            "  in-process  {:>10.0} req/s   ({:.3} s wall)",
            self.inproc_throughput_rps, self.inproc_wall_s
        );
        println!(
            "  wire        {:>10.0} req/s   ({:.3} s wall)  => {:.2}x \
             in-process",
            self.throughput_rps, self.wire_wall_s, self.wire_vs_inprocess
        );
        println!(
            "  latency ms  mean {:.3}  p50 {:.3}  p95 {:.3}  p99 {:.3}",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms
        );
        println!(
            "  mean batch rows {:.2}   errors {}   shed_429 {}",
            self.mean_batch_rows, self.errors, self.shed_429
        );
    }
}

/// Interleave the request sequence across `clients` lanes.
fn lanes(seq: &[usize], clients: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); clients.max(1)];
    for (j, &idx) in seq.iter().enumerate() {
        out[j % clients.max(1)].push(idx);
    }
    out
}

/// Serialize one `/v1/forward` body.
fn forward_body(adapter: &str, row: &[f32]) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("adapter").str_val(adapter);
    w.key("rows").begin_arr();
    w.begin_arr();
    for &v in row {
        w.f32_val(v);
    }
    w.end_arr();
    w.end_arr();
    w.end_obj();
    w.finish()
}

/// Run one wire scenario (see module docs).  Configs are taken as
/// final — apply `env_overridden()` at the call site.
pub fn run_wire(opts: &WireBenchOpts) -> anyhow::Result<WireBenchReport> {
    anyhow::ensure!(opts.adapters > 0, "need at least one adapter");
    anyhow::ensure!(opts.requests > 0, "need at least one request");
    anyhow::ensure!(opts.clients > 0, "need at least one client");
    anyhow::ensure!(
        opts.clients <= crate::wire::http::MAX_HTTP_WORKERS,
        "--wire-clients is capped at {} (each closed-loop client holds \
         one keep-alive connection, and a connection holds its HTTP \
         worker)",
        crate::wire::http::MAX_HTTP_WORKERS
    );
    // The bench must measure a hermetic synthetic fleet: a configured
    // warm-preload directory (meant for real gateways) would load
    // foreign checkpoints into the wire pass only — or fail the run on
    // a missing dir — skewing the wire-vs-in-process comparison.
    let mut serve_cfg = opts.serve.clone();
    serve_cfg.preload_dir.clear();
    let budget = serve_cfg.cache_budget_bytes();
    let n = opts.site.n;

    // Zipf request sequence + input pool, shared by both passes.
    let mut rng = crate::math::rng::Pcg64::new(opts.seed ^ 0x5eed);
    let zipf = Zipf::new(opts.adapters, opts.zipf);
    let seq: Vec<usize> =
        (0..opts.requests).map(|_| zipf.sample(&mut rng)).collect();
    let pool: Vec<Vec<f32>> =
        (0..X_POOL).map(|_| rng.normal_vec(n, 1.0)).collect();
    let lane_idx = lanes(&seq, opts.clients);

    // -- pass 1: in-process closed loop at the same concurrency --
    let (registry, names) = synthetic_registry(
        opts.adapters,
        opts.site,
        opts.core_a,
        opts.core_b,
        opts.seed,
        budget,
    )?;
    let server = Server::new(registry, &serve_cfg);
    let workers = server.worker_count();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for lane in &lane_idx {
            let server = &server;
            let names = &names;
            let pool = &pool;
            s.spawn(move || {
                for (j, &idx) in lane.iter().enumerate() {
                    let x = pool[j % X_POOL].clone();
                    let ticket = server
                        .submit_row(&names[idx], x)
                        .expect("in-process submit");
                    let _ = ticket.wait().expect("in-process answer");
                }
            });
        }
    });
    let inproc_wall_s = t0.elapsed().as_secs_f64();
    drop(server);

    // -- pass 2: the same workload over HTTP --
    let (registry, _) = synthetic_registry(
        opts.adapters,
        opts.site,
        opts.core_a,
        opts.core_b,
        opts.seed,
        budget,
    )?;
    // The transport is thread-per-connection: every closed-loop bench
    // client holds one keep-alive connection for the whole run, so a
    // pool smaller than `clients` — auto-sized OR explicitly
    // configured — would strand lanes in the accept queue until their
    // 30 s client timeouts count as errors.  Pin at least one HTTP
    // worker per lane.
    let mut wire_cfg = opts.wire.clone();
    wire_cfg.http_workers = wire_cfg.http_workers.max(opts.clients);
    let mut gw = Gateway::start(registry, &serve_cfg, &wire_cfg)?;
    let addr = gw.addr();
    let errors = AtomicU64::new(0);
    let mut lat_by_lane: Vec<Vec<f64>> = Vec::new();
    let t0 = Instant::now();
    std::thread::scope(|s| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for lane in &lane_idx {
            let names = &names;
            let pool = &pool;
            let errors = &errors;
            handles.push(s.spawn(move || -> Vec<f64> {
                let mut client = match HttpClient::connect(addr) {
                    Ok(c) => c,
                    Err(_) => {
                        errors.fetch_add(
                            lane.len() as u64,
                            Ordering::Relaxed,
                        );
                        return Vec::new();
                    }
                };
                let mut lat = Vec::with_capacity(lane.len());
                for (j, &idx) in lane.iter().enumerate() {
                    let body = forward_body(
                        &names[idx],
                        &pool[j % X_POOL],
                    );
                    let t = Instant::now();
                    match client.request(
                        "POST",
                        "/v1/forward",
                        Some(body.as_bytes()),
                    ) {
                        Ok(resp) if resp.status == 200 => {
                            lat.push(
                                t.elapsed().as_secs_f64() * 1e3,
                            );
                        }
                        _ => {
                            errors
                                .fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                lat
            }));
        }
        for h in handles {
            lat_by_lane.push(h.join().expect("bench client thread"));
        }
        Ok(())
    })?;
    let wire_wall_s = t0.elapsed().as_secs_f64();
    let stats = gw.state().server().scheduler_stats();
    let (batches, rows) = (stats.batches, stats.batched_rows);
    let shed_429 = gw.state().shed_429.load(Ordering::Relaxed);
    gw.shutdown();

    let mut lat_ms: Vec<f64> =
        lat_by_lane.into_iter().flatten().collect();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mean_ms = if lat_ms.is_empty() {
        0.0
    } else {
        lat_ms.iter().sum::<f64>() / lat_ms.len() as f64
    };
    let reqs = opts.requests as f64;
    let inproc_tp = reqs / inproc_wall_s.max(1e-9);
    let tp = reqs / wire_wall_s.max(1e-9);
    Ok(WireBenchReport {
        opts: opts.clone(),
        workers,
        inproc_wall_s,
        wire_wall_s,
        inproc_throughput_rps: inproc_tp,
        throughput_rps: tp,
        wire_vs_inprocess: tp / inproc_tp.max(1e-9),
        mean_ms,
        p50_ms: percentile(&lat_ms, 0.50),
        p95_ms: percentile(&lat_ms, 0.95),
        p99_ms: percentile(&lat_ms, 0.99),
        mean_batch_rows: rows as f64 / (batches as f64).max(1.0),
        errors: errors.load(Ordering::Relaxed),
        shed_429,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_smoke_scenario_reports_consistent_numbers() {
        let opts = WireBenchOpts {
            adapters: 3,
            requests: 32,
            clients: 2,
            zipf: 1.1,
            site: SiteShape { m: 16, n: 12 },
            core_a: 4,
            core_b: 3,
            seed: 5,
            serve: ServeConfig {
                cache_mb: 4.0,
                max_batch: 4,
                max_wait_us: 300,
                workers: 2,
                ..ServeConfig::default()
            },
            wire: WireConfig {
                port: 0,
                http_workers: 2,
                ..WireConfig::default()
            },
        };
        let rep = run_wire(&opts).unwrap();
        assert_eq!(rep.errors, 0, "every wire request must succeed");
        assert_eq!(rep.shed_429, 0);
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.inproc_throughput_rps > 0.0);
        assert!(rep.wire_vs_inprocess > 0.0);
        assert!(rep.p50_ms <= rep.p95_ms && rep.p95_ms <= rep.p99_ms);
        let j = rep.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(32));
        assert_eq!(j.get("clients").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("errors").unwrap().as_usize(), Some(0));
        assert!(j.get("wire_vs_inprocess").unwrap().as_f64().is_some());
    }
}
