//! `wire` — the zero-dependency network edge over the serve scheduler.
//!
//! CoSA's deployment story (§4 scalability) is many cheap adapters —
//! a compact core set plus a seed per task — multiplexed over one base
//! model.  That only pays off if remote clients can reach the engine:
//! [`serve`](crate::serve) is transport-agnostic, and this subsystem
//! is its production ingress, built entirely on `std` (the workspace
//! is offline/vendored — no hyper, no serde):
//!
//! * [`json`] — a strict, streaming JSON tokenizer/parser and an
//!   escaping writer with precise `f32` round-trips for row payloads
//!   (hardened separately from the trusting `util::json` file codec).
//! * [`http`] — a minimal HTTP/1.1 server over `std::net`: bounded
//!   accept/worker model, keep-alive, `Content-Length` framing,
//!   read/write timeouts, and the 400/404/413/429/503 error mapping.
//! * [`api`] — the JSON endpoints: `POST /v1/forward` (adapter name +
//!   per-site rows → per-site output rows, honoring per-request
//!   deadlines through the scheduler's ticket API),
//!   `POST /v1/adapters/{name}/load` + `DELETE /v1/adapters/{name}`
//!   (checkpoint hot load / evict through the shared
//!   [`AdaptedModel`](crate::model::AdaptedModel)), `GET /v1/stats`,
//!   and `GET /healthz`.
//! * [`gateway`] — lifecycle glue: owns the scheduler, warm pre-loads
//!   `[serve] preload_dir` checkpoints at startup, sheds with `429 +
//!   Retry-After` when the batch queue or the projection LRU thrashes
//!   past the `[wire]` watermarks, and drains in-flight tickets on
//!   shutdown.
//! * [`bench`] — the loopback wire workload behind `serve-bench
//!   --wire` (`serving_wire` report section, CI-gated: wire throughput
//!   must hold ≥ 0.5× the in-process batched engine).
//!
//! Knobs live in the `[wire]` config table
//! ([`config::WireConfig`](crate::config::WireConfig)) with
//! `COSA_WIRE_*` env overrides; the `serve` CLI subcommand runs a
//! gateway in the foreground.

pub mod api;
pub mod bench;
pub mod gateway;
pub mod http;
pub mod json;

pub use gateway::Gateway;
pub use http::{HttpClient, HttpServer};
