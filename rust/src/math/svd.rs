//! Randomized truncated SVD (Halko–Martinsson–Tropp) — the substrate for
//! PiSSA initialization: principal singular triplets of the frozen W0 seed
//! the A/B adapters, and the residual replaces W0.
//!
//! One of the two heaviest host-side matmul consumers (with the RIP
//! estimator): the range finder and sketch products route through the
//! `linalg` backend, using the transpose-free `gemm_tn` kernels instead
//! of materializing `Aᵀ` / `Qᵀ` copies per power iteration.

use crate::linalg;
use crate::math::matrix::Matrix;
use crate::math::rng::Pcg64;

pub struct Svd {
    /// Left singular vectors, (m × k), columns orthonormal.
    pub u: Matrix,
    /// Singular values, descending, length k.
    pub s: Vec<f32>,
    /// Right singular vectors transposed, (k × n), rows orthonormal.
    pub vt: Matrix,
}

/// Rank-`k` randomized SVD of `a` with `n_iter` subspace iterations.
///
/// Oversamples by `p = min(8, …)` then truncates; `n_iter = 4` is plenty
/// for the Gaussian-spectrum matrices this framework generates.
pub fn randomized_svd(a: &Matrix, k: usize, n_iter: usize,
                      rng: &mut Pcg64) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let k = k.min(m).min(n);
    let p = (k + 8).min(n.min(m)); // oversampled sketch size

    // Range finder: Q spans the dominant column space of A.
    let omega = Matrix::gaussian(n, p, 1.0, rng);
    let mut q = linalg::gemm(a, &omega).qr_q();
    for _ in 0..n_iter {
        q = linalg::gemm_tn(a, &q).qr_q(); // Aᵀ·Q without forming Aᵀ
        q = linalg::gemm(a, &q).qr_q();
    }

    // B = Qᵀ A  (p × n);  SVD of the small B via one-sided Jacobi on Bᵀ.
    let b = linalg::gemm_tn(&q, a);
    let (ub, s, vtb) = jacobi_svd(&b);

    // U = Q · U_b, truncated to k.
    let u_full = linalg::gemm(&q, &ub);
    let mut u = Matrix::zeros(m, k);
    let mut vt = Matrix::zeros(k, n);
    for i in 0..k {
        for r in 0..m {
            u.set(r, i, u_full.at(r, i));
        }
        for c in 0..n {
            vt.set(i, c, vtb.at(i, c));
        }
    }
    Svd { u, s: s[..k].to_vec(), vt }
}

/// Full SVD of a small matrix via one-sided Jacobi rotations on columns
/// of Aᵀ — O(n²·sweeps) but only ever applied to (k+8)-sized sketches.
/// Returns (U, s, Vᵀ) with s descending.
pub fn jacobi_svd(a: &Matrix) -> (Matrix, Vec<f32>, Matrix) {
    let (m, n) = (a.rows, a.cols);
    // Work on columns of G = Aᵀ (n × m): one-sided Jacobi orthogonalizes
    // rows of A; we instead orthogonalize columns of A directly when m>=n.
    // Standard trick: run on W = A if m >= n else on Aᵀ and swap outputs.
    if m < n {
        let (u, s, vt) = jacobi_svd(&a.transpose());
        return (vt.transpose(), s, u.transpose());
    }
    // W: m × n, V: n × n accumulating right rotations.
    let mut w: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a.at(i, j) as f64).collect())
        .collect();
    let mut v = vec![vec![0.0f64; n]; n];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }

    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let alpha: f64 = (0..m).map(|i| w[p][i] * w[p][i]).sum();
                let beta: f64 = (0..m).map(|i| w[q][i] * w[q][i]).sum();
                let gamma: f64 = (0..m).map(|i| w[p][i] * w[q][i]).sum();
                off += gamma * gamma;
                if gamma.abs() < 1e-14 * (alpha * beta).sqrt().max(1e-300) {
                    continue;
                }
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[p][i];
                    let wq = w[q][i];
                    w[p][i] = c * wp - s * wq;
                    w[q][i] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[p][i];
                    let vq = v[q][i];
                    v[p][i] = c * vp - s * vq;
                    v[q][i] = s * vp + c * vq;
                }
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
    }

    // Singular values are column norms of W; U = W / s.
    let mut triples: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 =
                (0..m).map(|i| w[j][i] * w[j][i]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    triples.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut s = vec![0.0f32; n];
    let mut vt = Matrix::zeros(n, n);
    for (rank, (norm, j)) in triples.iter().enumerate() {
        s[rank] = *norm as f32;
        if *norm > 1e-12 {
            for i in 0..m {
                u.set(i, rank, (w[*j][i] / norm) as f32);
            }
        }
        for i in 0..n {
            vt.set(rank, i, v[*j][i] as f32);
        }
    }
    (u, s, vt)
}

impl Svd {
    /// Reconstruct U diag(s) Vᵀ (tests / residual computation).
    pub fn reconstruct(&self) -> Matrix {
        let k = self.s.len();
        let mut us = Matrix::zeros(self.u.rows, k);
        for i in 0..self.u.rows {
            for j in 0..k {
                us.set(i, j, self.u.at(i, j) * self.s[j]);
            }
        }
        us.matmul(&self.vt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn jacobi_reconstructs_small() {
        let mut rng = Pcg64::new(10);
        let a = Matrix::gaussian(8, 5, 1.0, &mut rng);
        let (u, s, vt) = jacobi_svd(&a);
        let mut us = Matrix::zeros(8, 5);
        for i in 0..8 {
            for j in 0..5 {
                us.set(i, j, u.at(i, j) * s[j]);
            }
        }
        let rec = us.matmul(&vt);
        assert!(rec.sub(&a).frobenius() / a.frobenius() < 1e-4);
        // descending singular values
        assert!(s.windows(2).all(|w| w[0] >= w[1] - 1e-6));
    }

    #[test]
    fn jacobi_wide_matrix() {
        let mut rng = Pcg64::new(11);
        let a = Matrix::gaussian(4, 9, 1.0, &mut rng);
        let (u, s, vt) = jacobi_svd(&a);
        assert_eq!((u.rows, vt.cols), (4, 9));
        let k = s.len();
        let mut us = Matrix::zeros(4, k);
        for i in 0..4 {
            for j in 0..k {
                us.set(i, j, u.at(i, j) * s[j]);
            }
        }
        assert!(us.matmul(&vt).sub(&a).frobenius() / a.frobenius() < 1e-4);
    }

    #[test]
    fn randomized_svd_captures_low_rank() {
        // Build an exactly rank-3 matrix; rank-3 RSVD must nail it.
        let mut rng = Pcg64::new(12);
        let u = Matrix::gaussian(30, 3, 1.0, &mut rng);
        let v = Matrix::gaussian(3, 20, 1.0, &mut rng);
        let a = u.matmul(&v);
        let svd = randomized_svd(&a, 3, 4, &mut rng);
        let rec = svd.reconstruct();
        assert!(
            rec.sub(&a).frobenius() / a.frobenius() < 1e-3,
            "relative err {}",
            rec.sub(&a).frobenius() / a.frobenius()
        );
    }

    #[test]
    fn rsvd_truncation_error_bounded_by_tail() {
        prop::for_all("rsvd tail bound", 5, |rng| {
            let m = prop::int_in(rng, 10, 24);
            let n = prop::int_in(rng, 10, 24);
            let a = Matrix::gaussian(m, n, 1.0, rng);
            let k = 4.min(m).min(n);
            let svd = randomized_svd(&a, k, 4, rng);
            let err = svd.reconstruct().sub(&a).frobenius();
            // Compare to exact truncation error from full Jacobi SVD.
            let (_, s_full, _) = jacobi_svd(&a);
            let tail: f64 = s_full[k..]
                .iter()
                .map(|x| (*x as f64) * (*x as f64))
                .sum::<f64>()
                .sqrt();
            assert!(
                err <= tail * 1.6 + 1e-4,
                "rsvd err {err} vs optimal tail {tail}"
            );
        });
    }

    #[test]
    fn singular_vectors_orthonormal() {
        let mut rng = Pcg64::new(13);
        let a = Matrix::gaussian(25, 12, 1.0, &mut rng);
        let svd = randomized_svd(&a, 5, 3, &mut rng);
        let utu = svd.u.transpose().matmul(&svd.u);
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - want).abs() < 1e-3);
            }
        }
    }
}
