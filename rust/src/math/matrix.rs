//! Row-major dense f32 matrix: storage, norms and QR (for randomized
//! SVD).  All products delegate to the `linalg` backend layer — matmul
//! variants here are thin ergonomic wrappers over `linalg::gemm*`, and
//! sparse-core products live in `linalg::sparse`.

use crate::math::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// i.i.d. N(0, sigma²) entries from a deterministic generator.
    pub fn gaussian(rows: usize, cols: usize, sigma: f64,
                    rng: &mut Pcg64) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, sigma) }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self (r×k) · other (k×c)` on the active `linalg` backend.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        crate::linalg::gemm(self, other)
    }

    /// `self (r×k) · otherᵀ` for other (c×k) — no transpose materialized.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        crate::linalg::gemm_nt(self, other)
    }

    /// `selfᵀ · other` for self (k×r), other (k×c) — no transpose
    /// materialized.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        crate::linalg::gemm_tn(self, other)
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn frobenius_sq(&self) -> f64 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum()
    }
    pub fn frobenius(&self) -> f64 {
        self.frobenius_sq().sqrt()
    }

    /// Column L2 norms (DoRA's direction normalizer).
    pub fn col_norms(&self) -> Vec<f32> {
        let mut out = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                let v = self.data[i * self.cols + j] as f64;
                out[j] += v * v;
            }
        }
        out.into_iter().map(|v| v.sqrt() as f32).collect()
    }

    /// Thin QR via modified Gram–Schmidt; returns Q (rows × cols).
    /// Requires rows >= cols; rank deficiency is tolerated (zero columns).
    pub fn qr_q(&self) -> Matrix {
        assert!(self.rows >= self.cols);
        let (m, n) = (self.rows, self.cols);
        // work column-major for stability bookkeeping
        let mut cols: Vec<Vec<f64>> = (0..n)
            .map(|j| (0..m).map(|i| self.at(i, j) as f64).collect())
            .collect();
        for j in 0..n {
            for k in 0..j {
                let dot: f64 =
                    (0..m).map(|i| cols[k][i] * cols[j][i]).sum();
                for i in 0..m {
                    cols[j][i] -= dot * cols[k][i];
                }
            }
            let norm: f64 =
                (0..m).map(|i| cols[j][i] * cols[j][i]).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for i in 0..m {
                    cols[j][i] /= norm;
                }
            } else {
                for i in 0..m {
                    cols[j][i] = 0.0;
                }
            }
        }
        let mut q = Matrix::zeros(m, n);
        for j in 0..n {
            for i in 0..m {
                q.set(i, j, cols[j][i] as f32);
            }
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity_property() {
        prop::for_all("A·I == A", 20, |rng| {
            let n = prop::int_in(rng, 1, 12);
            let m = prop::int_in(rng, 1, 12);
            let a = Matrix::gaussian(m, n, 1.0, rng);
            let c = a.matmul(&Matrix::identity(n));
            for (x, y) in a.data.iter().zip(&c.data) {
                assert!((x - y).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn matmul_associativity() {
        prop::for_all("(AB)C == A(BC)", 10, |rng| {
            let (m, k, l, n) = (
                prop::int_in(rng, 1, 8),
                prop::int_in(rng, 1, 8),
                prop::int_in(rng, 1, 8),
                prop::int_in(rng, 1, 8),
            );
            let a = Matrix::gaussian(m, k, 1.0, rng);
            let b = Matrix::gaussian(k, l, 1.0, rng);
            let c = Matrix::gaussian(l, n, 1.0, rng);
            let lhs = a.matmul(&b).matmul(&c);
            let rhs = a.matmul(&b.matmul(&c));
            for (x, y) in lhs.data.iter().zip(&rhs.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        });
    }

    #[test]
    fn matmul_nt_tn_match_explicit_transposes() {
        prop::for_all("A·Bᵀ and Aᵀ·B wrappers", 15, |rng| {
            let m = prop::int_in(rng, 1, 10);
            let k = prop::int_in(rng, 1, 12);
            let n = prop::int_in(rng, 1, 10);
            let a = Matrix::gaussian(m, k, 1.0, rng);
            let bt = Matrix::gaussian(n, k, 1.0, rng);
            let at = Matrix::gaussian(k, m, 1.0, rng);
            let b = Matrix::gaussian(k, n, 1.0, rng);
            let nt = a.matmul_nt(&bt);
            let nt_ref = a.matmul(&bt.transpose());
            for (x, y) in nt.data.iter().zip(&nt_ref.data) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
            let tn = at.matmul_tn(&b);
            let tn_ref = at.transpose().matmul(&b);
            for (x, y) in tn.data.iter().zip(&tn_ref.data) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        });
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(1);
        let a = Matrix::gaussian(5, 9, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn qr_orthonormal_and_spans() {
        let mut rng = Pcg64::new(2);
        let a = Matrix::gaussian(20, 6, 1.0, &mut rng);
        let q = a.qr_q();
        let qtq = q.transpose().matmul(&q);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (qtq.at(i, j) - want).abs() < 1e-4,
                    "QtQ[{i},{j}] = {}",
                    qtq.at(i, j)
                );
            }
        }
        // Q Qᵀ A == A (Q spans A's column space when A has full column rank)
        let proj = q.matmul(&q.transpose()).matmul(&a);
        assert!(proj.sub(&a).frobenius() / a.frobenius() < 1e-4);
    }

    #[test]
    fn col_norms_match_manual() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 2.0]);
        let n = a.col_norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn frobenius_norm() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.frobenius() - 5.0).abs() < 1e-9);
    }
}
