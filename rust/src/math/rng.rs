//! Deterministic PCG64 RNG + Gaussian sampling (Box–Muller).
//!
//! This is the *adapter-defining* RNG: the paper stores only the core Y and
//! a seed, regenerating the fixed projections L and R at load time.  The
//! stream therefore has to be stable across runs, platforms and versions —
//! PCG XSL-RR 128/64 with fixed constants, no platform-dependent state.

/// PCG XSL-RR 128/64 (the `pcg64` reference generator).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with stream id 0 (the framework derives sub-streams by key).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent generator for a named tensor — used so every
    /// L/R projection depends only on (adapter_seed, tensor_name).
    pub fn derive(seed: u64, name: &str) -> Self {
        // FNV-1a over the name selects the PCG stream.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Self::with_stream(seed, h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (uses both outputs).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of N(0, sigma²) f32 samples.
    pub fn normal_vec(&mut self, len: usize, sigma: f64) -> Vec<f32> {
        (0..len).map(|_| (self.normal() * sigma) as f32).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn derive_isolates_tensors() {
        let xs: Vec<u64> = (0..8)
            .map(|_| Pcg64::derive(7, "adp.0.wq.l").next_u64())
            .collect();
        assert!(xs.windows(2).all(|w| w[0] == w[1]));
        assert_ne!(
            Pcg64::derive(7, "adp.0.wq.l").next_u64(),
            Pcg64::derive(7, "adp.0.wq.r").next_u64()
        );
        assert_ne!(
            Pcg64::derive(7, "adp.0.wq.l").next_u64(),
            Pcg64::derive(8, "adp.0.wq.l").next_u64()
        );
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut rng = Pcg64::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(6);
        for _ in 0..20 {
            let s = rng.sample_indices(30, 10);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 10);
            assert!(s.iter().all(|&i| i < 30));
        }
    }

    /// Regression pin: the adapter format depends on this exact stream.
    #[test]
    fn golden_stream_values() {
        let mut rng = Pcg64::new(0);
        let first = rng.next_u64();
        let mut rng2 = Pcg64::new(0);
        assert_eq!(first, rng2.next_u64());
        // value pinned at first implementation; changing the RNG breaks
        // every stored adapter, so fail loudly.
        let mut rng3 = Pcg64::new(0xC05A);
        let v: Vec<u64> = (0..3).map(|_| rng3.next_u64()).collect();
        assert_eq!(v.len(), 3);
        assert!(v[0] != v[1] && v[1] != v[2]);
    }
}
