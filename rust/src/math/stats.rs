//! Statistics + the GLUE metric zoo (paper §5.1): accuracy, F1, Matthews
//! correlation, Pearson/Spearman, percentiles, mean/std aggregation.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (n-1 denominator; 0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100].  Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Classification accuracy.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hit = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hit as f64 / pred.len() as f64
}

/// Binary F1 with class 1 as positive (GLUE MRPC convention).
pub fn f1_binary(pred: &[usize], gold: &[usize]) -> f64 {
    let tp = pred.iter().zip(gold).filter(|(p, g)| **p == 1 && **g == 1).count() as f64;
    let fp = pred.iter().zip(gold).filter(|(p, g)| **p == 1 && **g == 0).count() as f64;
    let fn_ = pred.iter().zip(gold).filter(|(p, g)| **p == 0 && **g == 1).count() as f64;
    if tp == 0.0 {
        return 0.0;
    }
    let prec = tp / (tp + fp);
    let rec = tp / (tp + fn_);
    2.0 * prec * rec / (prec + rec)
}

/// Matthews correlation coefficient (GLUE CoLA).
pub fn matthews_corr(pred: &[usize], gold: &[usize]) -> f64 {
    let tp = pred.iter().zip(gold).filter(|(p, g)| **p == 1 && **g == 1).count() as f64;
    let tn = pred.iter().zip(gold).filter(|(p, g)| **p == 0 && **g == 0).count() as f64;
    let fp = pred.iter().zip(gold).filter(|(p, g)| **p == 1 && **g == 0).count() as f64;
    let fn_ = pred.iter().zip(gold).filter(|(p, g)| **p == 0 && **g == 1).count() as f64;
    let denom = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fn_) / denom
    }
}

/// Pearson correlation (GLUE STS-B, with Spearman below).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..x.len() {
        let a = x[i] - mx;
        let b = y[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

/// Average ranks with ties sharing the mean rank.
fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// GLUE STS-B metric: average of Pearson and Spearman.
pub fn pearson_spearman_avg(x: &[f64], y: &[f64]) -> f64 {
    0.5 * (pearson(x, y) + spearman(x, y))
}

/// "mean ± std" formatting used by every experiment table.
pub fn fmt_mean_std(xs: &[f64]) -> String {
    format!("{:.2} ±{:.2}", mean(xs), std_dev(xs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 95.0) - 3.85).abs() < 1e-9);
    }

    #[test]
    fn accuracy_f1_mcc() {
        let pred = [1, 0, 1, 1, 0, 0];
        let gold = [1, 0, 0, 1, 1, 0];
        assert!((accuracy(&pred, &gold) - 4.0 / 6.0).abs() < 1e-12);
        // tp=2 fp=1 fn=1 → P=2/3 R=2/3 → F1=2/3
        assert!((f1_binary(&pred, &gold) - 2.0 / 3.0).abs() < 1e-12);
        let mcc = matthews_corr(&pred, &gold);
        assert!((mcc - (2.0 * 2.0 - 1.0) / 9.0).abs() < 1e-9, "{mcc}");
    }

    #[test]
    fn perfect_and_inverse_predictions() {
        let g = [0, 1, 0, 1];
        assert_eq!(matthews_corr(&g, &g), 1.0);
        let inv = [1, 0, 1, 0];
        assert_eq!(matthews_corr(&inv, &g), -1.0);
        assert_eq!(f1_binary(&g, &g), 1.0);
    }

    #[test]
    fn pearson_exact_linear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_invariance() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [1.0, 8.0, 27.0, 64.0, 125.0]; // nonlinear but monotone
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        assert!(pearson(&x, &y) < 1.0);
    }

    #[test]
    fn spearman_with_ties() {
        let x = [1.0, 2.0, 2.0, 3.0];
        let r = ranks(&x);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
        assert_eq!(f1_binary(&[0, 0], &[0, 0]), 0.0);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }
}
