//! Dense linear algebra, deterministic RNG and statistics substrates.
//!
//! Everything the framework needs numerically on the host side: Gaussian
//! projection generation (the paper's L/R dictionaries), randomized SVD
//! (PiSSA initialization), and the metric zoo for the GLUE-style evals.

pub mod matrix;
pub mod rng;
pub mod stats;
pub mod svd;

pub use matrix::Matrix;
pub use rng::Pcg64;
