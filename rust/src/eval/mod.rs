//! Evaluation harness: classification metrics over eval artifacts,
//! batched greedy decoding for LM tasks, exact-match / pass@1 / rubric
//! scoring (paper §5.1 "Evaluation Metrics").

use crate::data::batcher::{cls_batch, eval_windows, lm_batch, Batch};
use crate::data::tokenizer::{EOS, PAD, SEP};
use crate::data::{ClsDataset, LmDataset, LmExample, Vocab};
use crate::math::stats;
use crate::runtime::executor::{Executor, State};

/// Classification / regression eval: returns (mean loss, task metric).
/// Metric selected by `ds.metric`: acc | f1 | mcc | pearson_spearman.
pub fn eval_cls(exec: &Executor, state: &State, ds: &ClsDataset)
                -> anyhow::Result<(f64, f64)> {
    let m = &exec.meta.model;
    let regression = m.head == "reg";
    let (bsz, seq) = (m.batch, m.max_seq);
    let mut losses = Vec::new();
    let mut preds: Vec<usize> = Vec::new();
    let mut scores: Vec<f64> = Vec::new();
    let mut golds_i: Vec<usize> = Vec::new();
    let mut golds_f: Vec<f64> = Vec::new();

    for (idx, valid) in eval_windows(ds.eval.len(), bsz) {
        let exs: Vec<&_> = idx.iter().map(|i| &ds.eval[*i]).collect();
        let batch = cls_batch(&exs, bsz, seq, regression);
        let out = exec.eval_step(state, &batch)?;
        losses.push(out.loss as f64);
        let ncls = *out.logits_shape.last().unwrap();
        for b in 0..valid {
            let row = &out.logits[b * ncls..(b + 1) * ncls];
            if regression {
                scores.push(row[0] as f64);
                golds_f.push(exs[b].label as f64);
            } else {
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                preds.push(argmax);
                golds_i.push(exs[b].label as usize);
            }
        }
    }
    let metric = match ds.metric {
        "f1" => stats::f1_binary(&preds, &golds_i),
        "mcc" => stats::matthews_corr(&preds, &golds_i),
        "pearson_spearman" => stats::pearson_spearman_avg(&scores, &golds_f),
        _ => stats::accuracy(&preds, &golds_i),
    };
    Ok((stats::mean(&losses), metric))
}

/// LM eval loss + teacher-forced token accuracy on the eval split.
pub fn eval_lm(exec: &Executor, state: &State, ds: &LmDataset)
               -> anyhow::Result<(f64, f64)> {
    let m = &exec.meta.model;
    let (bsz, seq) = (m.batch, m.max_seq);
    let mut losses = Vec::new();
    let mut accs = Vec::new();
    for (idx, _valid) in eval_windows(ds.eval.len(), bsz) {
        let exs: Vec<&_> = idx.iter().map(|i| &ds.eval[*i]).collect();
        let batch = lm_batch(&exs, bsz, seq);
        let out = exec.eval_step(state, &batch)?;
        losses.push(out.loss as f64);
        accs.push(out.acc as f64);
    }
    Ok((stats::mean(&losses), stats::mean(&accs)))
}

/// Batched greedy decode: given prompts, autoregressively generate up to
/// `max_new` tokens (stopping at EOS) using the eval artifact's full
/// logits.  Returns one generated completion per example.
pub fn greedy_decode(exec: &Executor, state: &State,
                     examples: &[&LmExample], max_new: usize)
                     -> anyhow::Result<Vec<Vec<u32>>> {
    let m = &exec.meta.model;
    let (bsz, seq, vocab) = (m.batch, m.max_seq, m.vocab);
    let mut results: Vec<Vec<u32>> = vec![Vec::new(); examples.len()];

    for (widx, valid) in eval_windows(examples.len(), bsz) {
        // current sequences start as the prompts
        let mut seqs: Vec<Vec<u32>> = widx
            .iter()
            .map(|i| examples[*i].prompt.clone())
            .collect();
        let mut done = vec![false; bsz];
        for _ in 0..max_new {
            if done.iter().take(valid).all(|d| *d) {
                break;
            }
            let batch = decode_batch(&seqs, bsz, seq);
            let out = exec.eval_step(state, &batch)?;
            for b in 0..valid {
                if done[b] || seqs[b].len() >= seq {
                    done[b] = true;
                    continue;
                }
                let pos = seqs[b].len() - 1;
                let row = &out.logits
                    [(b * seq + pos) * vocab..(b * seq + pos + 1) * vocab];
                let next = row
                    .iter()
                    .enumerate()
                    .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                    .map(|(i, _)| i as u32)
                    .unwrap_or(EOS);
                seqs[b].push(next);
                if next == EOS {
                    done[b] = true;
                }
            }
        }
        for b in 0..valid {
            let plen = examples[widx[b]].prompt.len();
            results[widx[b]] = seqs[b][plen..].to_vec();
        }
    }
    Ok(results)
}

/// Assemble a decode batch: ids = current sequences, dummy targets,
/// wmask marks real tokens (needed for the padding-attention mask).
fn decode_batch(seqs: &[Vec<u32>], bsz: usize, seq: usize) -> Batch {
    let mut ids = vec![PAD as i32; bsz * seq];
    let mut wmask = vec![0.0f32; bsz * seq];
    for (b, s) in seqs.iter().enumerate().take(bsz) {
        for (t, tok) in s.iter().take(seq).enumerate() {
            ids[b * seq + t] = *tok as i32;
            wmask[b * seq + t] = 1.0;
        }
    }
    Batch {
        bsz,
        seq,
        ids,
        wmask,
        targets: Some(vec![PAD as i32; bsz * seq]),
        labels_i: None,
        labels_f: None,
        valid: seqs.len().min(bsz),
    }
}

/// Integer exact-match accuracy (GSM8K/MATH-style) of generated
/// completions against gold.
pub fn exact_match_int(v: &Vocab, examples: &[&LmExample],
                       generated: &[Vec<u32>]) -> f64 {
    let mut hit = 0usize;
    for (e, g) in examples.iter().zip(generated) {
        let gold = v.decode_int(&e.completion);
        let pred = v.decode_int(g);
        if gold.is_some() && gold == pred {
            hit += 1;
        }
    }
    hit as f64 / examples.len().max(1) as f64
}

/// Rubric-judge mean score (MT-Bench substitute, 0–10).
pub fn judge_score(examples: &[&LmExample], generated: &[Vec<u32>]) -> f64 {
    let scores: Vec<f64> = examples
        .iter()
        .zip(generated)
        .map(|(e, g)| crate::data::instr::judge(&e.completion, g))
        .collect();
    stats::mean(&scores)
}

/// Strict sequence exact match (token-level).
pub fn exact_match_seq(examples: &[&LmExample],
                       generated: &[Vec<u32>]) -> f64 {
    let strip = |xs: &[u32]| -> Vec<u32> {
        xs.iter().copied().take_while(|t| *t != EOS && *t != SEP).collect()
    };
    let mut hit = 0;
    for (e, g) in examples.iter().zip(generated) {
        if strip(&e.completion) == strip(g) {
            hit += 1;
        }
    }
    hit as f64 / examples.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::BOS;

    #[test]
    fn decode_batch_layout() {
        let seqs = vec![vec![BOS, 30, 31], vec![BOS, 40]];
        let b = decode_batch(&seqs, 4, 8);
        assert_eq!(b.ids[0..3], [BOS as i32, 30, 31]);
        assert_eq!(b.ids[3], PAD as i32);
        assert_eq!(b.wmask[8], 1.0);
        assert_eq!(b.wmask[10], 0.0);
        assert_eq!(b.valid, 2);
    }

    #[test]
    fn exact_match_int_scores() {
        let v = Vocab::new(64);
        let mk = |ans: i64| LmExample {
            prompt: vec![BOS, SEP],
            completion: {
                let mut c = v.encode_int(ans);
                c.push(EOS);
                c
            },
        };
        let e1 = mk(42);
        let e2 = mk(7);
        let exs = vec![&e1, &e2];
        let gen = vec![
            {
                let mut g = v.encode_int(42);
                g.push(EOS);
                g
            },
            v.encode_int(8),
        ];
        assert!((exact_match_int(&v, &exs, &gen) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn exact_match_seq_ignores_terminators() {
        let e = LmExample { prompt: vec![BOS], completion: vec![30, 31, EOS] };
        let exs = vec![&e];
        assert_eq!(exact_match_seq(&exs, &[vec![30, 31]]), 1.0);
        assert_eq!(exact_match_seq(&exs, &[vec![30, 32]]), 0.0);
    }
}
