//! `model` — the multi-site adapted-model layer.
//!
//! CoSA adapts *every* targeted projection of a transformer, and each
//! adapted site's artifact is only a compact core plus a seed that
//! regenerates its fixed projections (paper §4.1).  This module makes
//! "a whole adapted model" the system's default serving shape instead
//! of a single-matrix special case:
//!
//! * [`ModelSpec`] / [`SiteSpec`] — the shape contract: an ordered list
//!   of named `m × n` sites, each with its own core dims `(a, b)`
//!   (per-site heterogeneity is first-class — KaSA-style per-layer
//!   compression budgets).  Site names are the tensor stems projections
//!   regenerate from and checkpoint site blocks carry.
//! * [`AdaptedModel`] — one base, N sites, many named adapters (each a
//!   per-site set of [`crate::adapters::Adapter`] trait objects under
//!   one seed — CoSA, RoSA, and LoRA are served by the same engine),
//!   and **one** shared byte-budgeted [`ProjectionCache`] arbitrating
//!   residency over every regenerable tensor each method *declares*
//!   (CoSA's `L`/`R`; fully-stored methods declare none and bypass the
//!   cache entirely).  Two-phase [`AdaptedModel::plan`] /
//!   [`AdaptedModel::install`] resolves all cold tensors of a request
//!   in one locked call and regenerates outside the lock
//!   ([`ModelPlan::regen_missing`]).
//!
//! `serve` builds on this layer: its scheduler batches whole multi-site
//! requests and segments fused batches by (adapter, method), and
//! `serve::bench::run_model` measures the
//! shared-cache-vs-per-site-cache claim CI gates.  `config`'s `[model]`
//! table (`COSA_MODEL_*` env) constructs specs; `adapters::costmodel`
//! aggregates per-model param/byte accounting from the same spec.

pub mod adapted;
pub mod cache;
pub mod spec;

#[cfg(test)]
mod tests_determinism;

pub use adapted::{
    synthetic_sites, AdaptedModel, CoreInput, ModelAdapter, ModelHandles,
    ModelPlan, SiteHandles, SitePlan,
};
pub use cache::{CacheKey, CacheStats, ProjectionCache};
pub use spec::{ModelSpec, SiteShape, SiteSpec};
