//! `ModelSpec` — the shape contract of an adapted model: an ordered
//! list of named sites, each an `m × n` projection with its own CoSA
//! core dims `(a, b)`.
//!
//! Site names are load-bearing: they are the tensor stems the canonical
//! projection generators key off (`<site>.l` / `<site>.r`, exactly the
//! training-time convention `adp.<layer>.<proj>.l`), the keys checkpoint
//! v2 site blocks carry, and the ids multi-site registries match cores
//! against.  Per-site `(a, b)` is deliberately heterogeneous-capable
//! (KaSA-style per-layer compression budgets): nothing in the serving
//! stack assumes two sites share a core shape.

/// One adapted weight's shape: the adapted matrix is `m × n`
/// (activations enter as rows of width `n`, leave as rows of width `m`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SiteShape {
    pub m: usize,
    pub n: usize,
}

/// One named site of a model: shape plus the CoSA core dims used at it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SiteSpec {
    /// Tensor stem, e.g. "adp.0.wq" — projections regenerate from
    /// `<name>.l` / `<name>.r` unless an adapter overrides them.
    pub name: String,
    pub shape: SiteShape,
    /// Core `Y` is `a × b` at this site.
    pub a: usize,
    pub b: usize,
}

impl SiteSpec {
    /// Parse the compact `name:MxN:AxB` form used by config site lists
    /// (e.g. `"adp.0.wq:256x256:16x12"`).
    pub fn parse(s: &str) -> anyhow::Result<SiteSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            parts.len() == 3,
            "site spec `{s}` is not `name:MxN:AxB`"
        );
        let dims = |p: &str| -> anyhow::Result<(usize, usize)> {
            let (x, y) = p
                .split_once('x')
                .ok_or_else(|| anyhow::anyhow!("`{p}` is not `XxY` in `{s}`"))?;
            Ok((x.trim().parse()?, y.trim().parse()?))
        };
        let name = parts[0].trim();
        anyhow::ensure!(!name.is_empty(), "site spec `{s}` has no name");
        let (m, n) = dims(parts[1])?;
        let (a, b) = dims(parts[2])?;
        let spec = SiteSpec {
            name: name.to_string(),
            shape: SiteShape { m, n },
            a,
            b,
        };
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "site has an empty name");
        anyhow::ensure!(
            self.shape.m >= 1
                && self.shape.n >= 1
                && self.a >= 1
                && self.b >= 1,
            "site `{}`: every dim must be >= 1 (m {} n {} a {} b {})",
            self.name,
            self.shape.m,
            self.shape.n,
            self.a,
            self.b
        );
        Ok(())
    }

    /// Trainable parameters of one adapter at this site (`a·b`).
    pub fn core_params(&self) -> usize {
        self.a * self.b
    }

    /// Floats of regenerated projection state (`m·a + b·n`) — the
    /// per-site `ProjectionCache` working set of one adapter.
    pub fn projection_floats(&self) -> usize {
        self.shape.m * self.a + self.b * self.shape.n
    }

    /// Canonical projection tensor names for this site.
    pub fn l_name(&self) -> String {
        format!("{}.l", self.name)
    }
    pub fn r_name(&self) -> String {
        format!("{}.r", self.name)
    }
}

/// An adapted model: ordered named sites.  The order is the wire order —
/// multi-site requests carry one activation row per site in this order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub sites: Vec<SiteSpec>,
}

impl ModelSpec {
    /// Validating constructor.
    pub fn new(name: &str, sites: Vec<SiteSpec>) -> anyhow::Result<ModelSpec> {
        let spec = ModelSpec { name: name.to_string(), sites };
        spec.validate()?;
        Ok(spec)
    }

    /// One-site model (the PR-3 serving shape, now a special case).
    pub fn single(
        name: &str,
        shape: SiteShape,
        a: usize,
        b: usize,
    ) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            sites: vec![SiteSpec { name: name.to_string(), shape, a, b }],
        }
    }

    /// Synthetic `sites = N` preset for benches and quick configs:
    /// `N` sites named `site00…`, all `shape`-sized, with deliberately
    /// heterogeneous cores — odd sites get half the core dims (KaSA-style
    /// per-layer budgets), so multi-site paths never silently assume a
    /// uniform `(a, b)`.
    pub fn synthetic(
        sites: usize,
        shape: SiteShape,
        a: usize,
        b: usize,
    ) -> ModelSpec {
        let site = |i: usize| {
            let (aa, bb) = if i % 2 == 1 {
                ((a / 2).max(1), (b / 2).max(1))
            } else {
                (a, b)
            };
            SiteSpec { name: format!("site{i:02}"), shape, a: aa, b: bb }
        };
        ModelSpec {
            name: format!("synthetic-{sites}"),
            sites: (0..sites).map(site).collect(),
        }
    }

    /// Build from config site-list strings (`name:MxN:AxB` each).
    pub fn from_site_list(
        name: &str,
        list: &[String],
    ) -> anyhow::Result<ModelSpec> {
        let sites = list
            .iter()
            .map(|s| SiteSpec::parse(s))
            .collect::<anyhow::Result<Vec<_>>>()?;
        ModelSpec::new(name, sites)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.sites.is_empty(),
            "model `{}` has no sites",
            self.name
        );
        for s in &self.sites {
            s.validate()?;
        }
        for (i, s) in self.sites.iter().enumerate() {
            let dup =
                self.sites[..i].iter().position(|t| t.name == s.name);
            if let Some(j) = dup {
                anyhow::bail!(
                    "model `{}`: sites {j} and {i} share the name `{}`",
                    self.name,
                    s.name
                );
            }
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.sites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    pub fn site_index(&self, name: &str) -> Option<usize> {
        self.sites.iter().position(|s| s.name == name)
    }

    /// Trainable parameters of one adapter over the whole model
    /// (`Σ a·b` — the model-level analogue of the paper's per-site `ab`).
    pub fn core_params(&self) -> usize {
        self.sites.iter().map(|s| s.core_params()).sum()
    }

    /// Regenerated projection floats across all sites (`Σ m·a + b·n`) —
    /// one adapter's full working set in the shared `ProjectionCache`.
    pub fn projection_floats(&self) -> usize {
        self.sites.iter().map(|s| s.projection_floats()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_dims() {
        let s = SiteSpec::parse("adp.0.wq:256x128:16x12").unwrap();
        assert_eq!(s.name, "adp.0.wq");
        assert_eq!(s.shape, SiteShape { m: 256, n: 128 });
        assert_eq!((s.a, s.b), (16, 12));
        assert_eq!(s.core_params(), 192);
        assert_eq!(s.projection_floats(), 256 * 16 + 12 * 128);
        assert_eq!(s.l_name(), "adp.0.wq.l");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "", "noname", "a:2x2", ":2x2:1x1", "a:2x:1x1", "a:2x2:0x1",
            "a:2x2:1x1:extra", "a:x2:1x1",
        ] {
            assert!(SiteSpec::parse(bad).is_err(), "`{bad}` must fail");
        }
    }

    #[test]
    fn spec_validates_names_and_dims() {
        let shape = SiteShape { m: 4, n: 4 };
        let dup = ModelSpec::new(
            "m",
            vec![
                SiteSpec { name: "x".into(), shape, a: 1, b: 1 },
                SiteSpec { name: "x".into(), shape, a: 1, b: 1 },
            ],
        );
        assert!(dup.is_err(), "duplicate site names must fail");
        assert!(ModelSpec::new("m", vec![]).is_err(), "zero sites");
        let zero = ModelSpec::new(
            "m",
            vec![SiteSpec { name: "x".into(), shape, a: 0, b: 1 }],
        );
        assert!(zero.is_err(), "zero core dim");
    }

    #[test]
    fn synthetic_is_heterogeneous_and_ordered() {
        let spec = ModelSpec::synthetic(4, SiteShape { m: 32, n: 24 }, 8, 6);
        assert_eq!(spec.len(), 4);
        spec.validate().unwrap();
        assert_eq!(spec.sites[0].name, "site00");
        assert_eq!((spec.sites[0].a, spec.sites[0].b), (8, 6));
        assert_eq!((spec.sites[1].a, spec.sites[1].b), (4, 3),
                   "odd sites get half-size cores");
        assert_eq!(spec.site_index("site03"), Some(3));
        assert_eq!(spec.core_params(), 2 * (8 * 6) + 2 * (4 * 3));
    }

    #[test]
    fn single_site_is_a_one_site_model() {
        let spec =
            ModelSpec::single("adp.0.wq", SiteShape { m: 12, n: 10 }, 4, 3);
        assert_eq!(spec.len(), 1);
        assert_eq!(spec.core_params(), 12);
        assert_eq!(spec.projection_floats(), 12 * 4 + 3 * 10);
    }
}
