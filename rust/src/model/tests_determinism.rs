// lint: allow-file(panic) — `#[cfg(test)]`-only module (gated at the `mod` declaration, which per-file lexing cannot see): test asserts are the contract here.
//! The §4.1 serving-determinism suite, migrated from the retired
//! `serve::registry` shim (the registry *was* [`AdaptedModel`] behind a
//! type alias; the model layer owns its contract tests directly now):
//! evict → reload bit-identity, disk round-trips, cache-stats
//! accounting, raced plan/install splits.

use std::sync::Arc;

use crate::adapters::cosa::{
    adapter_forward, regen_l, regen_r, CosaAdapter,
};
use crate::math::matrix::Matrix;
use crate::math::rng::Pcg64;
use crate::model::{AdaptedModel, CoreInput, ModelSpec, SiteShape};
use crate::train::checkpoint::Checkpoint;

fn test_registry(budget: usize) -> AdaptedModel {
    AdaptedModel::single_site(
        "adp.0.wq",
        SiteShape { m: 12, n: 10 },
        4,
        3,
        budget,
    )
}

fn add_adapter(reg: &mut AdaptedModel, name: &str, seed: u64) {
    let mut rng = Pcg64::derive(seed, name);
    let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
    reg.insert(
        name,
        seed,
        2.0,
        vec![CoreInput::new("adp.0.wq.l", "adp.0.wq.r", y)],
    )
    .unwrap();
}

#[test]
fn forward_matches_direct_adapter_math() {
    let mut reg = test_registry(1 << 20);
    add_adapter(&mut reg, "a", 7);
    let mut rng = Pcg64::new(1);
    let x = Matrix::gaussian(3, 10, 1.0, &mut rng);
    let got = reg.forward_one("a", &x).unwrap();
    let l = regen_l(7, "adp.0.wq.l", 12, 4);
    let r = regen_r(7, "adp.0.wq.r", 3, 10);
    let h = reg.handles("a").unwrap();
    let y = h.sites[0]
        .adapter
        .as_any()
        .downcast_ref::<CosaAdapter>()
        .unwrap()
        .core_arc();
    let want = adapter_forward(&x, &l, &r, &y, 2.0);
    assert_eq!(got, want, "registry forward must be the canonical math");
}

#[test]
fn unknown_adapter_is_an_error() {
    let mut reg = test_registry(1 << 20);
    let x = Matrix::zeros(1, 10);
    assert!(reg.forward_one("nope", &x).is_err());
    assert!(!reg.evict("nope"));
}

#[test]
fn cache_hits_after_first_touch() {
    let mut reg = test_registry(1 << 20);
    add_adapter(&mut reg, "a", 7);
    let x = Matrix::zeros(1, 10);
    reg.forward_one("a", &x).unwrap();
    let s1 = reg.cache_stats();
    assert_eq!((s1.hits, s1.misses), (0, 2), "first touch: L and R miss");
    reg.forward_one("a", &x).unwrap();
    let s2 = reg.cache_stats();
    assert_eq!((s2.hits, s2.misses), (2, 2), "second touch: both hit");
}

#[test]
fn cache_is_never_touched_by_storage_free_methods() {
    // LoRA declares no regenerable tensors: serving it must leave the
    // shared projection cache completely untouched — no hits, no
    // misses, no resident bytes.
    use crate::adapters::Method;
    let mut reg = test_registry(1 << 20);
    reg.insert_synthetic_method("lo", 7, 2.0, Method::LoRA).unwrap();
    let x = Matrix::zeros(1, 10);
    reg.forward_one("lo", &x).unwrap();
    reg.forward_one("lo", &x).unwrap();
    let s = reg.cache_stats();
    assert_eq!((s.hits, s.misses), (0, 0), "lora must bypass the cache");
    assert_eq!(reg.cache_bytes(), 0);
}

#[test]
fn lru_evicts_by_byte_budget_and_keeps_newest() {
    // Budget fits exactly one adapter's projections: L 12x4 + R 3x10
    // = 78 floats = 312 bytes.  Two adapters must thrash; the newest
    // projections always stay resident.
    let mut reg = test_registry(312);
    add_adapter(&mut reg, "a", 7);
    add_adapter(&mut reg, "b", 8);
    let x = Matrix::zeros(1, 10);
    reg.forward_one("a", &x).unwrap();
    reg.forward_one("b", &x).unwrap();
    let s = reg.cache_stats();
    assert_eq!(s.misses, 4, "all four projections regenerate");
    assert!(s.evictions >= 2, "budget forces evictions: {s:?}");
    reg.forward_one("a", &x).unwrap();
    let s = reg.cache_stats();
    assert_eq!(s.misses, 6, "a's projections were evicted, regen again");
}

#[test]
fn zero_budget_still_serves() {
    let mut reg = test_registry(0);
    add_adapter(&mut reg, "a", 7);
    let mut rng = Pcg64::new(2);
    let x = Matrix::gaussian(2, 10, 1.0, &mut rng);
    let o1 = reg.forward_one("a", &x).unwrap();
    let o2 = reg.forward_one("a", &x).unwrap();
    assert_eq!(o1, o2, "regen-every-time must still be deterministic");
}

#[test]
fn evict_reload_is_bit_identical() {
    // The §4.1 determinism contract end-to-end: load -> forward,
    // evict (adapter AND cached projections via a tiny budget),
    // reload -> forward must agree bit-for-bit.
    let mut reg = test_registry(312);
    add_adapter(&mut reg, "a", 7);
    let mut rng = Pcg64::new(3);
    let x = Matrix::gaussian(5, 10, 1.0, &mut rng);
    let before = reg.forward_one("a", &x).unwrap();
    assert!(reg.evict("a"));
    // churn the projection cache so "a" is fully cold again
    add_adapter(&mut reg, "churn", 9);
    reg.forward_one("churn", &x).unwrap();
    add_adapter(&mut reg, "a", 7);
    let after = reg.forward_one("a", &x).unwrap();
    for (p, q) in before.data.iter().zip(&after.data) {
        assert_eq!(p.to_bits(), q.to_bits(), "evict/reload drifted");
    }
}

#[test]
fn checkpoint_roundtrip_load_by_name_bit_identical() {
    use std::collections::BTreeMap;
    let dir = std::env::temp_dir().join("cosa_serve_registry_test");
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Pcg64::new(4);
    let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
    let mut tensors = BTreeMap::new();
    tensors.insert("adp.0.wq.y".to_string(),
                   (vec![4usize, 3], y.data.clone()));
    let ck = Checkpoint {
        version: 2,
        method: "cosa".into(),
        adapter_seed: 77,
        artifact: "tiny-lm_cosa".into(),
        step: 5,
        sites: Vec::new(),
        tensors,
    };
    ck.save(&dir.join("mathbot.cosa")).unwrap();

    let mut reg = test_registry(1 << 20);
    reg.load_from_dir(&dir, "mathbot", 2.0).unwrap();
    let x = Matrix::gaussian(2, 10, 1.0, &mut rng);
    let first = reg.forward_one("mathbot", &x).unwrap();

    // evict + reload from disk: same bits
    assert!(reg.evict("mathbot"));
    reg.load_from_dir(&dir, "mathbot", 2.0).unwrap();
    let second = reg.forward_one("mathbot", &x).unwrap();
    for (p, q) in first.data.iter().zip(&second.data) {
        assert_eq!(p.to_bits(), q.to_bits(), "disk reload drifted");
    }

    // and the in-memory insert with the same parts agrees too
    let mut reg2 = test_registry(1 << 20);
    reg2.insert(
        "mathbot",
        77,
        2.0,
        vec![CoreInput::new("adp.0.wq.l", "adp.0.wq.r", y)],
    )
    .unwrap();
    let third = reg2.forward_one("mathbot", &x).unwrap();
    assert_eq!(first, third, "checkpoint path vs direct insert");
}

#[test]
fn multi_site_checkpoint_roundtrip_from_disk() {
    // The site-aware flow end-to-end through the filesystem: one
    // adapter name carries all per-site cores, load_from_dir
    // reassembles the whole model-adapter bit-identically.
    let dir = std::env::temp_dir().join("cosa_serve_registry_v2_test");
    std::fs::create_dir_all(&dir).unwrap();
    let spec = ModelSpec::synthetic(
        3, SiteShape { m: 12, n: 10 }, 4, 3);
    let mut reg = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
    let mut rng = Pcg64::new(8);
    let ys: Vec<Matrix> = spec
        .sites
        .iter()
        .map(|s| Matrix::gaussian(s.a, s.b, 0.5, &mut rng))
        .collect();
    reg.insert_synthetic("fleet", 42, 2.0, ys).unwrap();
    let ck = reg.checkpoint("fleet", "tiny-lm_cosa").unwrap();
    ck.save(&dir.join("fleet.cosa")).unwrap();

    let xs: Vec<Matrix> = spec
        .sites
        .iter()
        .map(|s| Matrix::gaussian(2, s.shape.n, 1.0, &mut rng))
        .collect();
    let want = reg.forward("fleet", &xs).unwrap();

    let mut fresh = AdaptedModel::new(spec, 1 << 20).unwrap();
    fresh.load_from_dir(&dir, "fleet", 2.0).unwrap();
    let got = fresh.forward("fleet", &xs).unwrap();
    for (wm, gm) in want.iter().zip(&got) {
        for (p, q) in wm.data.iter().zip(&gm.data) {
            assert_eq!(p.to_bits(), q.to_bits(),
                       "disk site-aware round-trip drifted");
        }
    }
}

#[test]
fn plan_install_split_matches_inline_and_survives_races() {
    let mut reg = test_registry(1 << 20);
    add_adapter(&mut reg, "a", 7);
    // Two cold plans (as two workers would take under the lock).
    let p1 = reg.plan("a").unwrap();
    let p2 = reg.plan("a").unwrap();
    let s1 = &p1.sites[0];
    assert!(s1.have.iter().all(|h| h.is_none()), "cold cache");
    assert_eq!(s1.specs.len(), 2, "CoSA declares [L, R]");
    // Both regenerate outside the lock (regen_missing materializes
    // through the canonical generators the specs carry)...
    let (r1, r2) = (p1.regen_missing(), p2.regen_missing());
    // ...first install wins, second gets the already-resident Arcs.
    let h1 = reg.install(&p1, r1);
    let h2 = reg.install(&p2, r2);
    assert!(Arc::ptr_eq(&h1.sites[0].regen[0], &h2.sites[0].regen[0]),
            "raced install must dedupe");
    assert!(Arc::ptr_eq(&h1.sites[0].regen[1], &h2.sites[0].regen[1]));
    // the specs name the canonical generators' keys
    assert_eq!(s1.specs[0].key(), (7, "adp.0.wq.l".to_string(), 12, 4));
    assert_eq!(s1.specs[1].key(), (7, "adp.0.wq.r".to_string(), 3, 10));
    // and a warm plan resolves without any regeneration step
    let p3 = reg.plan("a").unwrap();
    assert!(p3.sites[0].have.iter().all(|h| h.is_some()), "warm cache");
    let no = p3.no_regen();
    let h3 = reg.install(&p3, no);
    assert!(Arc::ptr_eq(&h1.sites[0].regen[0], &h3.sites[0].regen[0]));
    // inline handles() agrees with the split path
    let h4 = reg.handles("a").unwrap();
    assert!(Arc::ptr_eq(&h1.sites[0].regen[0], &h4.sites[0].regen[0])
        && Arc::ptr_eq(&h1.sites[0].regen[1], &h4.sites[0].regen[1]));
}

#[test]
fn load_checkpoint_requires_a_core() {
    let ck = Checkpoint {
        version: 2,
        method: "lora".into(),
        adapter_seed: 1,
        artifact: "tiny-lm_lora".into(),
        step: 0,
        sites: Vec::new(),
        tensors: std::collections::BTreeMap::new(),
    };
    let mut reg = test_registry(1 << 20);
    assert!(reg.load_checkpoint("x", &ck, 2.0).is_err());
}
