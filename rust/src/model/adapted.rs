//! `AdaptedModel` — one base model, N adapted sites, many named
//! adapters of *any servable method*, one shared byte-budgeted
//! [`ProjectionCache`].
//!
//! The model layer programs against the method-agnostic
//! [`Adapter`] trait: a registered adapter is a **per-site set of
//! trait objects** (one `Arc<dyn Adapter>` per [`SiteSpec`] of the
//! [`ModelSpec`]), and everything residency-related keys on each
//! method's *declared* regenerable tensors ([`Adapter::regen_specs`])
//! rather than hard-coding CoSA's `L`/`R` pair.  CoSA sites declare
//! `[L, R]` in exactly the order the pre-trait code peeked the cache,
//! so its key sequence — and therefore its bit-identical serving — is
//! preserved by construction; LoRA/RoSA sites declare nothing and
//! simply never touch the cache.  The projection LRU stays deliberately
//! shared across sites: one byte budget arbitrates residency over every
//! `(site, adapter)` pair, so a hot adapter keeps its entire per-model
//! projection set warm while cold sites age out (`serve::bench`
//! measures shared-vs-per-site and CI gates the ratio).
//!
//! The two-phase [`AdaptedModel::plan`] / [`AdaptedModel::install`]
//! lookup extends the single-site split to whole requests: one `plan`
//! call under the lock resolves every warm regenerable tensor and
//! describes **all cold ones at once** (as [`RegenSpec`]s), so a
//! scheduler worker materializes every missing tensor of a request
//! outside the lock in one go ([`ModelPlan::regen_missing`]) rather
//! than re-taking the lock per site.
//!
//! Cache residents are [`QuantMat`]s: regeneration always happens in
//! f32 (bit-identical to training), then the model's configured cache
//! codec ([`AdaptedModel::set_cache_quant`]) encodes the tensor **once
//! at install time** — bf16/int8 residents halve/quarter the byte
//! budget a projection set occupies, and the Packed backend up-converts
//! inside its pack step on use.  The default `F32` codec wraps the
//! regenerated matrix without copying, keeping the serving path
//! bit-identical to the unquantized engine.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::adapters::cosa::CosaAdapter;
use crate::adapters::traits::{self, Adapter, RegenSpec};
use crate::adapters::Method;
use crate::linalg::{QuantKind, QuantMat, Workspace};
use crate::math::matrix::Matrix;
use crate::model::cache::{CacheStats, ProjectionCache};
use crate::model::spec::{ModelSpec, SiteShape};
use crate::train::checkpoint::{Checkpoint, CkptSite, FORMAT_VERSION};

/// Insert-side description of one CoSA site core: the trained `Y` plus
/// the tensor names its projections regenerate from (must match what
/// training used or the regenerated `L`/`R` differ).
pub struct CoreInput {
    pub l_name: String,
    pub r_name: String,
    pub y: Matrix,
}

impl CoreInput {
    pub fn new(l_name: &str, r_name: &str, y: Matrix) -> CoreInput {
        CoreInput {
            l_name: l_name.to_string(),
            r_name: r_name.to_string(),
            y,
        }
    }
}

/// One registered adapter: a per-site trait-object set under one
/// seed/alpha, all sites running the same method (the engine serves
/// uniform-method adapters; a *model* mixes methods by loading several
/// adapters).
#[derive(Clone)]
pub struct ModelAdapter {
    pub name: Arc<str>,
    pub seed: u64,
    pub alpha: f32,
    pub method: Method,
    /// Aligned with `ModelSpec::sites` (index i adapts site i).
    pub sites: Vec<Arc<dyn Adapter>>,
}

impl ModelAdapter {
    /// Trainable parameters across all sites.
    pub fn param_count(&self) -> usize {
        self.sites.iter().map(|s| s.param_count()).sum()
    }

    /// Stored (checkpoint-resident) bytes across all sites.
    pub fn resident_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.resident_bytes()).sum()
    }

    /// Seed-regenerable bytes across all sites (the projection-cache
    /// working set; 0 for fully-stored methods).
    pub fn regen_bytes(&self) -> usize {
        self.sites.iter().map(|s| s.regen_bytes()).sum()
    }
}

/// Per-site slice of a [`ModelPlan`]: `have[i]` is `Some` where
/// `specs[i]` was warm in the cache at plan time; cold slots carry the
/// [`RegenSpec`] to materialize outside the registry lock.
pub struct SitePlan {
    pub adapter: Arc<dyn Adapter>,
    /// The site's declared regenerable tensors, in declaration order
    /// (= the order `forward_into` expects and the cache is keyed).
    pub specs: Vec<RegenSpec>,
    /// Aligned with `specs`: cache hits resolved at plan time (already
    /// encoded with whatever codec was active when they were installed).
    pub have: Vec<Option<Arc<QuantMat>>>,
}

/// First phase of a whole-request lookup: every site of one adapter,
/// warm tensors resolved, cold tensors described (see module docs).
pub struct ModelPlan {
    pub alpha: f32,
    pub method: Method,
    pub sites: Vec<SitePlan>,
}

impl ModelPlan {
    /// Regeneration slots for [`AdaptedModel::install`] — `None`
    /// everywhere, for inline (lock-free) callers.
    pub fn no_regen(&self) -> Vec<Vec<Option<Matrix>>> {
        self.sites
            .iter()
            .map(|s| s.specs.iter().map(|_| None).collect())
            .collect()
    }

    /// `(warm, cold)` regenerable-tensor counts across the plan's
    /// sites — the projection-cache hit/miss split a request trace
    /// records (fully-stored methods report `(0, 0)`).
    pub fn cache_hits_misses(&self) -> (u32, u32) {
        let mut hits = 0u32;
        let mut misses = 0u32;
        for site in &self.sites {
            for have in &site.have {
                if have.is_some() {
                    hits = hits.saturating_add(1);
                } else {
                    misses = misses.saturating_add(1);
                }
            }
        }
        (hits, misses)
    }

    /// Materialize exactly the tensors the plan found cold — the
    /// outside-the-lock step of the plan/install split, method-agnostic
    /// (each slot regenerates from its own [`RegenSpec`]).
    pub fn regen_missing(&self) -> Vec<Vec<Option<Matrix>>> {
        self.sites
            .iter()
            .map(|s| {
                s.specs
                    .iter()
                    .zip(&s.have)
                    .map(|(spec, have)| {
                        have.is_none().then(|| spec.materialize())
                    })
                    .collect()
            })
            .collect()
    }
}

/// Everything one site's forward needs, `Arc`-shared so the registry
/// lock can be released before any compute starts.
#[derive(Clone)]
pub struct SiteHandles {
    pub adapter: Arc<dyn Adapter>,
    /// Materialized regenerable tensors in spec-declaration order
    /// (CoSA: `[L, R]`; LoRA/RoSA: empty), encoded with the model's
    /// cache codec at install time.
    pub regen: Vec<Arc<QuantMat>>,
}

/// Everything one *request's* forward needs: all sites of one adapter.
#[derive(Clone)]
pub struct ModelHandles {
    pub alpha: f32,
    pub method: Method,
    pub sites: Vec<SiteHandles>,
}

/// Multi-site, multi-method adapter registry over one [`ModelSpec`]
/// (see module docs).
pub struct AdaptedModel {
    spec: Arc<ModelSpec>,
    adapters: BTreeMap<Arc<str>, ModelAdapter>,
    cache: ProjectionCache,
    cache_quant: QuantKind,
}

impl AdaptedModel {
    /// Validating constructor: the spec is fixed for the model's
    /// lifetime; every adapter must conform to it.
    pub fn new(
        spec: ModelSpec,
        cache_budget_bytes: usize,
    ) -> anyhow::Result<AdaptedModel> {
        spec.validate()?;
        Ok(AdaptedModel {
            spec: Arc::new(spec),
            adapters: BTreeMap::new(),
            cache: ProjectionCache::new(cache_budget_bytes),
            cache_quant: QuantKind::F32,
        })
    }

    /// One-site model whose site stem is `site_name` (the PR-3 registry
    /// shape; infallible because the 1-site spec is valid by
    /// construction for nonzero dims — zero dims panic here, matching
    /// the old registry's insert-time check).
    pub fn single_site(
        site_name: &str,
        shape: SiteShape,
        a: usize,
        b: usize,
        cache_budget_bytes: usize,
    ) -> AdaptedModel {
        AdaptedModel::new(ModelSpec::single(site_name, shape, a, b),
                          cache_budget_bytes)
            // lint: allow(panic) — documented contract: zero dims panic at insert time (old registry behavior); a 1-site spec is otherwise valid by construction.
            .expect("single-site spec with nonzero dims is always valid")
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn spec_arc(&self) -> Arc<ModelSpec> {
        self.spec.clone()
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn reset_cache_stats(&mut self) {
        self.cache.reset_stats();
    }

    /// Resident projection bytes (diagnostic; see `ProjectionCache`).
    pub fn cache_bytes(&self) -> usize {
        self.cache.bytes()
    }

    /// Resident projection bytes split by storage codec
    /// (`[f32, bf16, int8]`) — the `/v1/stats` surface.
    pub fn cache_bytes_by_kind(&self) -> [usize; 3] {
        self.cache.resident_bytes_by_kind()
    }

    /// Resident projection tensor count — the quant bench's
    /// effective-capacity measure (a cheaper codec keeps more tensors
    /// resident in the same byte budget).
    pub fn cache_resident_count(&self) -> usize {
        self.cache.len()
    }

    /// Storage codec for cache-resident regenerated tensors (`[serve]
    /// cache_quant`).  Affects only **future** installs: tensors
    /// already resident keep the codec they were encoded with until the
    /// LRU ages them out — deterministic regeneration makes either copy
    /// correct, so there is nothing to invalidate.
    pub fn set_cache_quant(&mut self, kind: QuantKind) {
        self.cache_quant = kind;
    }

    pub fn cache_quant(&self) -> QuantKind {
        self.cache_quant
    }

    #[cfg(test)]
    pub(crate) fn cache(&self) -> &ProjectionCache {
        &self.cache
    }

    /// Registered adapter names (sorted — BTreeMap order).
    pub fn names(&self) -> Vec<Arc<str>> {
        self.adapters.keys().cloned().collect()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.adapters.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.adapters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adapters.is_empty()
    }

    /// Look up one registered adapter (wire stats/listing surface).
    pub fn get(&self, name: &str) -> Option<&ModelAdapter> {
        self.adapters.get(name)
    }

    /// Registered adapters in name order (wire listing surface).
    pub fn adapters(&self) -> impl Iterator<Item = &ModelAdapter> {
        self.adapters.values()
    }

    /// Hot-load an adapter from per-site trait objects, in spec order.
    /// Replaces any same-named adapter.  Every site must match the
    /// spec's `(m, n)` and all sites must run one method — the engine
    /// serves uniform-method adapters (mixed-method *models* are
    /// several adapters side by side).
    pub fn insert_sites(
        &mut self,
        name: &str,
        seed: u64,
        alpha: f32,
        sites: Vec<Arc<dyn Adapter>>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            sites.len() == self.spec.len(),
            "adapter `{name}`: {} sites for model `{}` with {} sites",
            sites.len(),
            self.spec.name,
            self.spec.len()
        );
        anyhow::ensure!(!sites.is_empty(), "adapter `{name}` has no sites");
        let method = sites[0].method();
        for (ad, site) in sites.iter().zip(&self.spec.sites) {
            anyhow::ensure!(
                ad.out_dim() == site.shape.m && ad.in_dim() == site.shape.n,
                "adapter `{name}` site `{}`: adapts {}x{}, spec wants \
                 {}x{}",
                site.name,
                ad.out_dim(),
                ad.in_dim(),
                site.shape.m,
                site.shape.n
            );
            anyhow::ensure!(
                ad.method() == method,
                "adapter `{name}` site `{}`: method `{}` differs from \
                 `{}` — one adapter serves one method",
                site.name,
                ad.method().name(),
                method.name()
            );
        }
        let key: Arc<str> = Arc::from(name);
        let adapter = ModelAdapter {
            name: key.clone(),
            seed,
            alpha,
            method,
            sites,
        };
        self.adapters.insert(key, adapter);
        Ok(())
    }

    /// Hot-load a CoSA adapter from its parts: one core per spec site,
    /// in spec order.  Every core must match its site's `(a, b)` —
    /// per-site heterogeneity lives in the spec, not in individual
    /// adapters.
    pub fn insert(
        &mut self,
        name: &str,
        seed: u64,
        alpha: f32,
        cores: Vec<CoreInput>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            cores.len() == self.spec.len(),
            "adapter `{name}`: {} cores for model `{}` with {} sites",
            cores.len(),
            self.spec.name,
            self.spec.len()
        );
        let mut sites: Vec<Arc<dyn Adapter>> =
            Vec::with_capacity(cores.len());
        for (core, site) in cores.into_iter().zip(&self.spec.sites) {
            anyhow::ensure!(
                core.y.rows == site.a && core.y.cols == site.b,
                "adapter `{name}` site `{}`: core is {}x{}, spec wants {}x{}",
                site.name,
                core.y.rows,
                core.y.cols,
                site.a,
                site.b
            );
            anyhow::ensure!(
                !core.l_name.is_empty() && !core.r_name.is_empty(),
                "adapter `{name}` site `{}`: empty projection tensor name",
                site.name
            );
            sites.push(Arc::new(CosaAdapter::new(
                seed,
                core.l_name,
                core.r_name,
                site.shape.m,
                site.shape.n,
                Arc::new(core.y),
            )));
        }
        self.insert_sites(name, seed, alpha, sites)
    }

    /// `insert` with the canonical projection names derived from the
    /// spec's site stems (`<site>.l` / `<site>.r`) — the synthetic-bench
    /// and freshly-trained-adapter path.
    pub fn insert_synthetic(
        &mut self,
        name: &str,
        seed: u64,
        alpha: f32,
        ys: Vec<Matrix>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            ys.len() == self.spec.len(),
            "adapter `{name}`: {} cores for {} sites",
            ys.len(),
            self.spec.len()
        );
        let cores = ys
            .into_iter()
            .zip(&self.spec.sites)
            .map(|(y, s)| CoreInput {
                l_name: s.l_name(),
                r_name: s.r_name(),
                y,
            })
            .collect();
        self.insert(name, seed, alpha, cores)
    }

    /// Deterministic synthetic adapter of any servable method — the
    /// bench and `[model] method` config path.  CoSA sites get gaussian
    /// `a × b` cores; LoRA/RoSA sites get rank-`a` factors (RoSA with a
    /// ~1/3-dense sparse residual on exact zeros).
    pub fn insert_synthetic_method(
        &mut self,
        name: &str,
        seed: u64,
        alpha: f32,
        method: Method,
    ) -> anyhow::Result<()> {
        let sites = synthetic_sites(&self.spec, method, seed, name)?;
        self.insert_sites(name, seed, alpha, sites)
    }

    /// Hot-load from a checkpoint.
    ///
    /// * **v2/v3** (site-aware header): every spec site must be covered
    ///   by a same-named checkpoint site block with matching `(m, n)`;
    ///   the per-site method tag (v3; v2 blocks are implicitly
    ///   `"cosa"`) picks the decoder, and CoSA blocks must additionally
    ///   match the spec's `(a, b)` core dims.
    /// * **v1** (no site metadata): CoSA only.  For a single-site model
    ///   the first 2-d `*.y` tensor (BTreeMap order) serves the site —
    ///   the PR-3 behavior, so old files keep loading as a 1-site
    ///   model.  For a multi-site model every spec site must find a
    ///   `<site>.y` tensor (matched **by name**, never by position —
    ///   tensor iteration order is lexicographic and silently binding
    ///   cores to the wrong sites would serve wrong math) with
    ///   matching dims.
    pub fn load_checkpoint(
        &mut self,
        name: &str,
        ck: &Checkpoint,
        alpha: f32,
    ) -> anyhow::Result<()> {
        let sites = if !ck.sites.is_empty() {
            self.sites_from_v2(name, ck)?
        } else {
            self.sites_from_v1(name, ck)?
        };
        self.insert_sites(name, ck.adapter_seed, alpha, sites)
    }

    fn sites_from_v2(
        &self,
        name: &str,
        ck: &Checkpoint,
    ) -> anyhow::Result<Vec<Arc<dyn Adapter>>> {
        let mut sites: Vec<Arc<dyn Adapter>> =
            Vec::with_capacity(self.spec.len());
        for site in &self.spec.sites {
            let blk = ck
                .sites
                .iter()
                .find(|c| c.name == site.name)
                .ok_or_else(|| anyhow::anyhow!(
                    "checkpoint for `{name}` has no site block `{}` \
                     (model `{}`)",
                    site.name,
                    self.spec.name
                ))?;
            anyhow::ensure!(
                blk.m == site.shape.m && blk.n == site.shape.n,
                "site `{}`: checkpoint adapts {}x{}, model spec wants \
                 {}x{}",
                site.name,
                blk.m,
                blk.n,
                site.shape.m,
                site.shape.n
            );
            let method = Method::from_str(&blk.method)?;
            if method == Method::CoSA {
                anyhow::ensure!(
                    blk.a == site.a && blk.b == site.b,
                    "site `{}`: checkpoint core is {}x{}, model spec \
                     wants {}x{}",
                    site.name,
                    blk.a,
                    blk.b,
                    site.a,
                    site.b
                );
            }
            let ad = traits::decode_site(
                method,
                &site.name,
                site.shape.m,
                site.shape.n,
                ck.adapter_seed,
                &ck.tensors,
            )?;
            anyhow::ensure!(
                ad.core_dims() == (blk.a, blk.b),
                "site `{}`: tensors decode to a {}x{} core, site block \
                 says {}x{}",
                site.name,
                ad.core_dims().0,
                ad.core_dims().1,
                blk.a,
                blk.b
            );
            sites.push(ad);
        }
        Ok(sites)
    }

    fn sites_from_v1(
        &self,
        name: &str,
        ck: &Checkpoint,
    ) -> anyhow::Result<Vec<Arc<dyn Adapter>>> {
        let ys: Vec<(&String, &(Vec<usize>, Vec<f32>))> = ck
            .tensors
            .iter()
            .filter(|(n, (shape, _))| n.ends_with(".y") && shape.len() == 2)
            .collect();
        anyhow::ensure!(
            !ys.is_empty(),
            "checkpoint for `{name}` has no 2-d `*.y` core tensor"
        );
        let picked: Vec<_> = if self.spec.len() == 1 {
            ys.into_iter().take(1).collect()
        } else {
            // Match by tensor stem == spec site name, order-independent.
            // A v1 file whose stems don't cover the spec is ambiguous —
            // refuse it rather than guess a positional binding.
            self.spec
                .sites
                .iter()
                .map(|site| {
                    let want = format!("{}.y", site.name);
                    ys.iter().find(|(n, _)| **n == want).copied().ok_or_else(
                        || anyhow::anyhow!(
                            "v1 checkpoint for `{name}` has no `{want}` \
                             core for site `{}` (v1 stems must match the \
                             model's site names; save a v2+ checkpoint to \
                             map sites explicitly)",
                            site.name
                        ),
                    )
                })
                .collect::<anyhow::Result<Vec<_>>>()?
        };
        let mut sites: Vec<Arc<dyn Adapter>> =
            Vec::with_capacity(picked.len());
        for ((tname, (shape, _)), site) in
            picked.into_iter().zip(&self.spec.sites)
        {
            anyhow::ensure!(
                shape.as_slice() == [site.a, site.b],
                "`{tname}`: shape {shape:?}, site `{}` wants [{}, {}]",
                site.name,
                site.a,
                site.b
            );
            // v1 projections derive from the *tensor* stem, not the
            // spec name — decode_site keys off whatever stem we pass
            let stem = tname.strip_suffix(".y").unwrap_or(tname);
            sites.push(traits::decode_site(
                Method::CoSA,
                stem,
                site.shape.m,
                site.shape.n,
                ck.adapter_seed,
                &ck.tensors,
            )?);
        }
        Ok(sites)
    }

    /// Load-by-name entry point: resolve `name` to a checkpoint file in
    /// `dir` (via [`Checkpoint::load_by_name`]) and hot-load it.
    pub fn load_from_dir(
        &mut self,
        dir: &Path,
        name: &str,
        alpha: f32,
    ) -> anyhow::Result<()> {
        let ck = Checkpoint::load_by_name(dir, name)?;
        self.load_checkpoint(name, &ck, alpha)
    }

    /// Snapshot a registered adapter as a v3 checkpoint (all per-site
    /// tensors under one name, one method tag per site block).  CoSA
    /// adapters must carry the canonical spec-derived projection names:
    /// a site-aware file records sites, not arbitrary tensor stems, so
    /// a custom-stem adapter would silently regenerate different
    /// projections after a round-trip — rejected here instead.
    pub fn checkpoint(
        &self,
        name: &str,
        artifact: &str,
    ) -> anyhow::Result<Checkpoint> {
        let adapter = self
            .adapters
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown adapter `{name}`"))?;
        let mut tensors = BTreeMap::new();
        let mut sites = Vec::with_capacity(self.spec.len());
        for (ad, site) in adapter.sites.iter().zip(&self.spec.sites) {
            if let Some(c) = ad.as_any().downcast_ref::<CosaAdapter>() {
                anyhow::ensure!(
                    c.l_name() == site.l_name()
                        && c.r_name() == site.r_name(),
                    "adapter `{name}` site `{}`: projection names \
                     (`{}`/`{}`) are not the canonical \
                     `<site>.l`/`<site>.r` — a site-aware checkpoint \
                     cannot represent them",
                    site.name,
                    c.l_name(),
                    c.r_name()
                );
            }
            ad.encode_tensors(&site.name, &mut tensors);
            let (a, b) = ad.core_dims();
            sites.push(CkptSite {
                name: site.name.clone(),
                m: site.shape.m,
                n: site.shape.n,
                a,
                b,
                method: ad.method().name().to_string(),
            });
        }
        Ok(Checkpoint {
            version: FORMAT_VERSION,
            method: adapter.method.name().to_string(),
            adapter_seed: adapter.seed,
            artifact: artifact.to_string(),
            step: 0,
            sites,
            tensors,
        })
    }

    /// Drop an adapter.  Its projections stay in the LRU until the byte
    /// budget pushes them out (another adapter may share the seed); a
    /// later reload regenerates bit-identically either way.
    pub fn evict(&mut self, name: &str) -> bool {
        self.adapters.remove(name).is_some()
    }

    /// Lock-friendly first phase of a whole-request lookup: cache hits
    /// resolve immediately into the plan; misses leave `have` slots as
    /// `None` plus the [`RegenSpec`] needed to materialize them
    /// **outside** whatever lock guards this model — all cold tensors
    /// of the request described by one call.  Hand the regenerated
    /// matrices back through [`AdaptedModel::install`].
    pub fn plan(&mut self, name: &str) -> anyhow::Result<ModelPlan> {
        // Split borrows: the adapter stays borrowed from `adapters`
        // while `cache` is touched mutably — cloning the whole adapter
        // here would put heap allocations inside the very lock the
        // plan/install split keeps brief.
        let adapter = self
            .adapters
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown adapter `{name}`"))?;
        let cache = &mut self.cache;
        let mut sites = Vec::with_capacity(self.spec.len());
        for ad in &adapter.sites {
            let specs = ad.regen_specs();
            let have = specs
                .iter()
                .map(|spec| cache.peek(&spec.key()))
                .collect();
            sites.push(SitePlan { adapter: ad.clone(), specs, have });
        }
        Ok(ModelPlan {
            alpha: adapter.alpha,
            method: adapter.method,
            sites,
        })
    }

    /// Second phase: install tensors regenerated outside the lock —
    /// one slot per declared spec per site, `None` for anything the
    /// plan already resolved (use [`ModelPlan::no_regen`] inline,
    /// [`ModelPlan::regen_missing`] for the outside-the-lock path).
    /// If two workers raced the same cold adapter, the first install
    /// wins and the loser's regenerated copies are dropped — both see
    /// identical bits either way, regeneration being deterministic.
    pub fn install(
        &mut self,
        plan: &ModelPlan,
        regen: Vec<Vec<Option<Matrix>>>,
    ) -> ModelHandles {
        assert_eq!(
            regen.len(),
            plan.sites.len(),
            "one regen slot set per planned site"
        );
        let mut sites = Vec::with_capacity(plan.sites.len());
        for (sp, slots) in plan.sites.iter().zip(regen) {
            assert_eq!(
                slots.len(),
                sp.specs.len(),
                "one regen slot per declared spec"
            );
            let mut mats = Vec::with_capacity(sp.specs.len());
            for ((spec, have), slot) in
                sp.specs.iter().zip(&sp.have).zip(slots)
            {
                let mat = match have {
                    Some(hit) => hit.clone(),
                    None => {
                        // Regenerate in f32 (slot, or inline), then
                        // encode once with the active codec — the
                        // quantized image is what goes resident.
                        let spec = spec.clone();
                        let kind = self.cache_quant;
                        self.cache.get_or(spec.key(), move || {
                            QuantMat::encode_owned(
                                slot.unwrap_or_else(|| spec.materialize()),
                                kind,
                            )
                        })
                    }
                };
                mats.push(mat);
            }
            sites.push(SiteHandles {
                adapter: sp.adapter.clone(),
                regen: mats,
            });
        }
        ModelHandles {
            alpha: plan.alpha,
            method: plan.method,
            sites,
        }
    }

    /// Handles for one whole-request forward, through the LRU.  Cache
    /// misses regenerate inline — single-owner callers (tests, the
    /// sequential bench baselines) hold no lock, so the two-phase split
    /// buys them nothing.
    pub fn handles(&mut self, name: &str) -> anyhow::Result<ModelHandles> {
        let plan = self.plan(name)?;
        let regen = plan.no_regen();
        Ok(self.install(&plan, regen))
    }

    /// [`AdaptedModel::plan`] for every adapter of a fused cross-adapter
    /// batch — one call under the lock describes **all** cold adapters
    /// at once, so a scheduler worker takes one lock round-trip per
    /// fused batch instead of one per adapter.  Per-name errors
    /// (unknown adapters) come back in place so one bad segment cannot
    /// sink its batchmates.
    pub fn plan_many(
        &mut self,
        names: &[&str],
    ) -> Vec<anyhow::Result<ModelPlan>> {
        names.iter().map(|n| self.plan(n)).collect()
    }

    /// [`AdaptedModel::install`] for a fused batch: one `(plan, regen)`
    /// pair per adapter segment, handles returned in order — again one
    /// locked call for the whole batch.
    pub fn install_many(
        &mut self,
        plans: &[ModelPlan],
        regens: Vec<Vec<Vec<Option<Matrix>>>>,
    ) -> Vec<ModelHandles> {
        assert_eq!(plans.len(), regens.len(), "one regen set per plan");
        plans
            .iter()
            .zip(regens)
            .map(|(p, r)| self.install(p, r))
            .collect()
    }

    /// Fused cross-adapter forward: row segment `g` of every `xs[i]`
    /// belongs to adapter `names[g]` (`segs[g]` rows, stacked in
    /// order), and all K adapters run through each site in **one**
    /// grouped dispatch ([`traits::forward_grouped_into`]) — maximal
    /// same-method segment runs take their method's grouped kernel
    /// path, so an all-CoSA batch executes exactly the pre-trait
    /// grouped block-diagonal sweep.  Bit-identical to slicing the
    /// rows apart and composing [`AdaptedModel::forward_into`] per
    /// adapter (asserted in tests).  Duplicate names are fine (their
    /// segments just share handles); any unknown name fails the whole
    /// call before outputs are touched.
    pub fn forward_grouped_into(
        &mut self,
        names: &[&str],
        segs: &[usize],
        xs: &[Matrix],
        ws: &mut Workspace,
        outs: &mut [Matrix],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            names.len() == segs.len(),
            "{} adapters for {} row segments",
            names.len(),
            segs.len()
        );
        let nsites = self.spec.len();
        anyhow::ensure!(
            xs.len() == nsites && outs.len() == nsites,
            "model `{}` has {} sites; got {} inputs / {} outputs",
            self.spec.name,
            nsites,
            xs.len(),
            outs.len()
        );
        let total: usize = segs.iter().sum();
        let mut handles = Vec::with_capacity(names.len());
        for name in names {
            let plan = self.plan(name)?;
            let regen = plan.no_regen();
            handles.push(self.install(&plan, regen));
        }
        let alphas: Vec<f32> = handles.iter().map(|h| h.alpha).collect();
        for (s, (x, out)) in xs.iter().zip(outs.iter_mut()).enumerate() {
            anyhow::ensure!(
                x.rows == total && out.rows == total,
                "site {s}: {} input rows / {} output rows for {} \
                 segment rows",
                x.rows,
                out.rows,
                total
            );
            let adapters: Vec<&dyn Adapter> = handles
                .iter()
                .map(|h| h.sites[s].adapter.as_ref())
                .collect();
            let regens: Vec<&[Arc<QuantMat>]> = handles
                .iter()
                .map(|h| h.sites[s].regen.as_slice())
                .collect();
            traits::forward_grouped_into(
                &adapters, &regens, &alphas, x, segs, ws, out,
            );
        }
        Ok(())
    }

    /// Workspace-backed multi-site forward: `xs[i]` (`N × n_i`) runs
    /// through site `i` into `outs[i]` (`N × m_i`) — exactly one
    /// [`Adapter::forward_into`] per site, so the result is
    /// bit-identical to composing independent single-site calls
    /// (asserted in tests).
    pub fn forward_into(
        &mut self,
        name: &str,
        xs: &[Matrix],
        ws: &mut Workspace,
        outs: &mut [Matrix],
    ) -> anyhow::Result<()> {
        let h = self.handles(name)?;
        anyhow::ensure!(
            xs.len() == h.sites.len() && outs.len() == h.sites.len(),
            "model `{}` has {} sites; got {} inputs / {} outputs",
            self.spec.name,
            h.sites.len(),
            xs.len(),
            outs.len()
        );
        for ((x, out), sh) in xs.iter().zip(outs.iter_mut()).zip(&h.sites) {
            sh.adapter.forward_into(x, &sh.regen, h.alpha, ws, out);
        }
        Ok(())
    }

    /// Allocating multi-site forward (tests and the sequential bench
    /// baselines): one output matrix per site.
    pub fn forward(
        &mut self,
        name: &str,
        xs: &[Matrix],
    ) -> anyhow::Result<Vec<Matrix>> {
        let h = self.handles(name)?;
        anyhow::ensure!(
            xs.len() == h.sites.len(),
            "model `{}` has {} sites; got {} inputs",
            self.spec.name,
            h.sites.len(),
            xs.len()
        );
        Ok(xs
            .iter()
            .zip(&h.sites)
            .map(|(x, sh)| sh.adapter.forward(x, &sh.regen, h.alpha))
            .collect())
    }

    /// Single-site sugar over [`AdaptedModel::forward`] for 1-site
    /// models (the PR-3 registry surface).
    pub fn forward_one(
        &mut self,
        name: &str,
        x: &Matrix,
    ) -> anyhow::Result<Matrix> {
        anyhow::ensure!(
            self.spec.len() == 1,
            "forward_one needs a 1-site model; `{}` has {} sites",
            self.spec.name,
            self.spec.len()
        );
        let mut outs = self.forward(name, std::slice::from_ref(x))?;
        outs.pop().ok_or_else(|| {
            anyhow::anyhow!("1-site forward yielded no output")
        })
    }
}

/// Deterministic synthetic per-site adapters of one method for a spec —
/// shared by [`AdaptedModel::insert_synthetic_method`], the serving
/// bench's mixed-method models, and tests.  LoRA/RoSA use each site's
/// CoSA `a` as the rank (clamped to the site dims); RoSA keeps every
/// third residual entry (exact zeros elsewhere).
pub fn synthetic_sites(
    spec: &ModelSpec,
    method: Method,
    seed: u64,
    salt: &str,
) -> anyhow::Result<Vec<Arc<dyn Adapter>>> {
    use crate::adapters::lora::LoraAdapter;
    use crate::adapters::rosa::RosaAdapter;
    use crate::math::rng::Pcg64;

    let mut sites: Vec<Arc<dyn Adapter>> = Vec::with_capacity(spec.len());
    for site in &spec.sites {
        let salted = format!("{salt}/{}", site.name);
        let mut rng = Pcg64::derive(seed, &salted);
        let (m, n) = (site.shape.m, site.shape.n);
        let r = site.a.min(m).min(n).max(1);
        let ad: Arc<dyn Adapter> = match method {
            Method::CoSA => {
                let y = Matrix::gaussian(site.a, site.b, 0.5, &mut rng);
                Arc::new(CosaAdapter::new(
                    seed,
                    site.l_name(),
                    site.r_name(),
                    m,
                    n,
                    Arc::new(y),
                ))
            }
            Method::LoRA => {
                let b = Matrix::gaussian(m, r, 0.5, &mut rng);
                let a = Matrix::gaussian(r, n, 0.5, &mut rng);
                Arc::new(LoraAdapter::try_new(Arc::new(b), Arc::new(a))?)
            }
            Method::RoSA => {
                let mut s = Matrix::gaussian(m, n, 0.5, &mut rng);
                for (i, v) in s.data.iter_mut().enumerate() {
                    if i % 3 != 0 {
                        *v = 0.0;
                    }
                }
                let b = Matrix::gaussian(m, r, 0.5, &mut rng);
                let a = Matrix::gaussian(r, n, 0.5, &mut rng);
                Arc::new(RosaAdapter::try_new(
                    Arc::new(s),
                    Arc::new(b),
                    Arc::new(a),
                )?)
            }
            other => anyhow::bail!(
                "method `{}` has no serving adapter implementation \
                 (servable: cosa, rosa, lora)",
                other.name()
            ),
        };
        sites.push(ad);
    }
    Ok(sites)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::cosa::{
        adapter_forward, adapter_forward_into, regen_l, regen_r,
    };
    use crate::math::rng::Pcg64;

    fn test_spec(sites: usize) -> ModelSpec {
        ModelSpec::synthetic(sites, SiteShape { m: 12, n: 10 }, 4, 3)
    }

    fn add_adapter(model: &mut AdaptedModel, name: &str, seed: u64) {
        let mut rng = Pcg64::derive(seed, name);
        let ys: Vec<Matrix> = model
            .spec()
            .sites
            .iter()
            .map(|s| Matrix::gaussian(s.a, s.b, 0.5, &mut rng))
            .collect();
        model.insert_synthetic(name, seed, 2.0, ys).unwrap();
    }

    fn site_inputs(spec: &ModelSpec, rows: usize, seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed);
        spec.sites
            .iter()
            .map(|s| Matrix::gaussian(rows, s.shape.n, 1.0, &mut rng))
            .collect()
    }

    #[test]
    fn multi_site_forward_is_bit_identical_to_independent_calls() {
        // The acceptance criterion: AdaptedModel's batched forward over
        // N heterogeneous sites == composing N independent single-site
        // adapter_forward_into calls, bit for bit.
        let spec = test_spec(3);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut model, "a", 7);
        let xs = site_inputs(&spec, 5, 1);

        let mut ws = Workspace::new();
        let mut outs: Vec<Matrix> = spec
            .sites
            .iter()
            .map(|s| Matrix::zeros(5, s.shape.m))
            .collect();
        model.forward_into("a", &xs, &mut ws, &mut outs).unwrap();

        let mut rng = Pcg64::derive(7, "a");
        for (i, site) in spec.sites.iter().enumerate() {
            let y = Matrix::gaussian(site.a, site.b, 0.5, &mut rng);
            let l = regen_l(7, &site.l_name(), site.shape.m, site.a);
            let r = regen_r(7, &site.r_name(), site.b, site.shape.n);
            let mut ws2 = Workspace::new();
            let mut want = Matrix::zeros(5, site.shape.m);
            adapter_forward_into(&xs[i], &l, &r, &y, 2.0, &mut ws2,
                                 &mut want);
            for (p, q) in outs[i].data.iter().zip(&want.data) {
                assert_eq!(p.to_bits(), q.to_bits(),
                           "site {i} diverged from the independent call");
            }
        }

        // the allocating forward agrees bitwise too (same kernels)
        let alloc = model.forward("a", &xs).unwrap();
        for (o, w) in alloc.iter().zip(&outs) {
            for (p, q) in o.data.iter().zip(&w.data) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
    }

    #[test]
    fn grouped_forward_is_bit_identical_to_per_adapter_batches() {
        // The fused-batching acceptance criterion: one grouped forward
        // over K adapters' stacked row segments == slicing the rows
        // apart and composing today's per-adapter forward_into calls,
        // bit for bit — zero-row segments included.
        let spec = test_spec(3);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        for (i, name) in ["a", "b", "c", "d"].iter().enumerate() {
            add_adapter(&mut model, name, 7 + i as u64);
        }
        let names = ["a", "b", "c", "d"];
        let segs = [2usize, 1, 0, 3];
        let total: usize = segs.iter().sum();
        let xs = site_inputs(&spec, total, 5);
        let mut ws = Workspace::new();
        let mut outs: Vec<Matrix> = spec
            .sites
            .iter()
            .map(|s| Matrix::zeros(total, s.shape.m))
            .collect();
        model
            .forward_grouped_into(&names, &segs, &xs, &mut ws, &mut outs)
            .unwrap();

        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let sub_xs: Vec<Matrix> = xs
                .iter()
                .map(|x| Matrix::from_vec(
                    rows,
                    x.cols,
                    x.data[row * x.cols..(row + rows) * x.cols].to_vec(),
                ))
                .collect();
            let mut sub_outs: Vec<Matrix> = spec
                .sites
                .iter()
                .map(|s| Matrix::zeros(rows, s.shape.m))
                .collect();
            model
                .forward_into(names[g], &sub_xs, &mut ws, &mut sub_outs)
                .unwrap();
            for (s, so) in sub_outs.iter().enumerate() {
                let m = spec.sites[s].shape.m;
                let fused = &outs[s].data[row * m..(row + rows) * m];
                for (e, (p, q)) in fused.iter().zip(&so.data).enumerate() {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "adapter {g} site {s} elem {e} diverged");
                }
            }
            row += rows;
        }

        // an unknown name fails the whole call before outputs move
        assert!(model
            .forward_grouped_into(&["a", "ghost"], &[1, 1],
                                  &site_inputs(&spec, 2, 6), &mut ws,
                                  &mut outs)
            .is_err());
    }

    #[test]
    fn mixed_method_grouped_forward_matches_per_adapter_batches() {
        // A model serving one adapter per method: the fused grouped
        // path must stay bit-identical to composed per-adapter calls
        // even when segment runs switch methods mid-batch.
        let spec = test_spec(2);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        for (name, method) in [
            ("c1", Method::CoSA),
            ("l1", Method::LoRA),
            ("r1", Method::RoSA),
            ("c2", Method::CoSA),
        ] {
            model
                .insert_synthetic_method(name, 40, 1.5, method)
                .unwrap();
        }
        let names = ["c1", "l1", "r1", "c2"];
        let segs = [2usize, 3, 1, 2];
        let total: usize = segs.iter().sum();
        let xs = site_inputs(&spec, total, 11);
        let mut ws = Workspace::new();
        let mut outs: Vec<Matrix> = spec
            .sites
            .iter()
            .map(|s| Matrix::zeros(total, s.shape.m))
            .collect();
        model
            .forward_grouped_into(&names, &segs, &xs, &mut ws, &mut outs)
            .unwrap();

        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            let sub_xs: Vec<Matrix> = xs
                .iter()
                .map(|x| Matrix::from_vec(
                    rows,
                    x.cols,
                    x.data[row * x.cols..(row + rows) * x.cols].to_vec(),
                ))
                .collect();
            let sub = model.forward(names[g], &sub_xs).unwrap();
            for (s, so) in sub.iter().enumerate() {
                let m = spec.sites[s].shape.m;
                let fused = &outs[s].data[row * m..(row + rows) * m];
                for (p, q) in fused.iter().zip(&so.data) {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "adapter {g} site {s} diverged");
                }
            }
            row += rows;
        }
        // method is visible per adapter (the wire stats surface)
        assert_eq!(model.get("l1").unwrap().method, Method::LoRA);
        assert_eq!(model.get("r1").unwrap().method, Method::RoSA);
        assert!(model.get("r1").unwrap().param_count() > 0);
        assert_eq!(model.get("l1").unwrap().regen_bytes(), 0);
        assert!(model.get("c1").unwrap().regen_bytes() > 0);
    }

    #[test]
    fn plan_many_reports_per_name_errors_in_place() {
        let mut model = AdaptedModel::new(test_spec(2), 1 << 20).unwrap();
        add_adapter(&mut model, "a", 7);
        let plans = model.plan_many(&["a", "ghost", "a"]);
        assert!(plans[0].is_ok());
        assert!(plans[1].is_err(), "unknown name must error in place");
        assert!(plans[2].is_ok(), "a bad segment must not sink batchmates");
        let ok: Vec<ModelPlan> =
            plans.into_iter().filter_map(|p| p.ok()).collect();
        let regens: Vec<_> = ok.iter().map(|p| p.no_regen()).collect();
        let hs = model.install_many(&ok, regens);
        assert_eq!(hs.len(), 2);
        // duplicate names in one batch share cache entries
        assert!(Arc::ptr_eq(&hs[0].sites[0].regen[0],
                            &hs[1].sites[0].regen[0]));
    }

    #[test]
    fn insert_enforces_spec_conformance() {
        let mut model = AdaptedModel::new(test_spec(2), 1 << 20).unwrap();
        let mut rng = Pcg64::new(1);
        // wrong core count
        let one = vec![Matrix::gaussian(4, 3, 0.5, &mut rng)];
        assert!(model.insert_synthetic("a", 7, 2.0, one).is_err());
        // wrong dims at site 1 (spec says 2x1 half-size core there)
        let bad = vec![
            Matrix::gaussian(4, 3, 0.5, &mut rng),
            Matrix::gaussian(4, 3, 0.5, &mut rng),
        ];
        assert!(model.insert_synthetic("a", 7, 2.0, bad).is_err());
        // conforming cores land
        let good = vec![
            Matrix::gaussian(4, 3, 0.5, &mut rng),
            Matrix::gaussian(2, 1, 0.5, &mut rng),
        ];
        model.insert_synthetic("a", 7, 2.0, good).unwrap();
        assert!(model.contains("a"));
        assert!(model.forward("nope", &site_inputs(model.spec(), 1, 2))
            .is_err());
    }

    #[test]
    fn insert_sites_enforces_dims_and_uniform_method() {
        let spec = test_spec(2);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        // mixed methods within one adapter are refused
        let mut mixed = synthetic_sites(&spec, Method::CoSA, 7, "x")
            .unwrap();
        mixed[1] =
            synthetic_sites(&spec, Method::LoRA, 7, "x").unwrap()[1]
                .clone();
        assert!(model.insert_sites("x", 7, 2.0, mixed).is_err());
        // wrong site dims are refused (build against a wider spec)
        let wide =
            ModelSpec::synthetic(2, SiteShape { m: 12, n: 11 }, 4, 3);
        let bad = synthetic_sites(&wide, Method::LoRA, 7, "x").unwrap();
        assert!(model.insert_sites("x", 7, 2.0, bad).is_err());
        // unservable synthetic methods are refused up front
        assert!(synthetic_sites(&spec, Method::DoRA, 7, "x").is_err());
        // conforming uniform-method sites land
        let good = synthetic_sites(&spec, Method::RoSA, 7, "x").unwrap();
        model.insert_sites("x", 7, 2.0, good).unwrap();
        assert_eq!(model.get("x").unwrap().method, Method::RoSA);
    }

    #[test]
    fn plan_resolves_all_cold_sites_at_once_and_install_dedupes() {
        let spec = test_spec(2);
        let mut model = AdaptedModel::new(spec, 1 << 20).unwrap();
        add_adapter(&mut model, "a", 7);
        // Two cold plans (as two workers would take under the lock):
        // every site is described in one call.
        let p1 = model.plan("a").unwrap();
        let p2 = model.plan("a").unwrap();
        assert_eq!(p1.sites.len(), 2);
        assert!(p1.sites.iter()
                    .all(|s| s.have.iter().all(|h| h.is_none())),
                "cold cache must leave every tensor to regenerate");
        assert!(p1.sites.iter().all(|s| s.specs.len() == 2),
                "CoSA sites declare [L, R]");
        // Both regenerate everything outside the lock...
        let (r1, r2) = (p1.regen_missing(), p2.regen_missing());
        assert!(r1.iter().flatten().all(|slot| slot.is_some()));
        let h1 = model.install(&p1, r1);
        let h2 = model.install(&p2, r2);
        for (s1, s2) in h1.sites.iter().zip(&h2.sites) {
            for (m1, m2) in s1.regen.iter().zip(&s2.regen) {
                assert!(Arc::ptr_eq(m1, m2), "raced install must dedupe");
            }
        }
        // warm plan resolves without any regeneration step
        let p3 = model.plan("a").unwrap();
        assert!(p3.sites.iter()
                    .all(|s| s.have.iter().all(|h| h.is_some())));
        assert!(p3.regen_missing().iter().flatten()
                    .all(|slot| slot.is_none()),
                "warm plans regenerate nothing");
        let no = p3.no_regen();
        let h3 = model.install(&p3, no);
        assert!(Arc::ptr_eq(&h1.sites[0].regen[0],
                            &h3.sites[0].regen[0]));
        // inline handles() agrees with the split path
        let h4 = model.handles("a").unwrap();
        assert!(Arc::ptr_eq(&h1.sites[1].regen[1],
                            &h4.sites[1].regen[1]));
    }

    #[test]
    fn shared_cache_accounting_is_exact_across_sites() {
        // Tight budget + heterogeneous sites + several adapters: the
        // shared LRU thrashes across sites, and the byte ledger must
        // stay exact — one site's evictions never corrupt another's
        // accounting (the satellite's cross-site cache test).
        let spec = test_spec(3);
        // one adapter's full projection set in bytes
        let full: usize = spec.projection_floats() * 4;
        let mut model = AdaptedModel::new(spec.clone(), full).unwrap();
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            add_adapter(&mut model, name, 7 + i as u64);
        }
        let xs = site_inputs(&spec, 2, 3);
        for round in 0..3 {
            for name in ["a", "b", "c"] {
                model.forward(name, &xs).unwrap();
                let c = model.cache();
                assert_eq!(c.bytes(), c.recomputed_bytes(),
                           "ledger drift: round {round} adapter {name}");
                assert!(c.bytes() <= full,
                        "budget exceeded with >1 entry resident");
            }
        }
        let s = model.cache_stats();
        assert!(s.evictions > 0, "scenario must actually thrash: {s:?}");
        // determinism under thrash: evict + reload is bit-identical
        let before = model.forward("a", &xs).unwrap();
        assert!(model.evict("a"));
        add_adapter(&mut model, "a", 7);
        let after = model.forward("a", &xs).unwrap();
        for (bm, am) in before.iter().zip(&after) {
            for (p, q) in bm.data.iter().zip(&am.data) {
                assert_eq!(p.to_bits(), q.to_bits(), "evict/reload drifted");
            }
        }
    }

    #[test]
    fn v3_checkpoint_roundtrips_all_sites_bit_identically() {
        let spec = test_spec(3);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut model, "fleet", 42);
        let ck = model.checkpoint("fleet", "tiny-lm_cosa").unwrap();
        assert_eq!(ck.version, FORMAT_VERSION);
        assert_eq!(ck.sites.len(), 3);
        assert!(ck.sites.iter().all(|s| s.method == "cosa"));

        let xs = site_inputs(&spec, 4, 9);
        let want = model.forward("fleet", &xs).unwrap();

        let mut fresh = AdaptedModel::new(spec, 1 << 20).unwrap();
        fresh.load_checkpoint("fleet", &ck, 2.0).unwrap();
        let got = fresh.forward("fleet", &xs).unwrap();
        for (wm, gm) in want.iter().zip(&got) {
            for (p, q) in wm.data.iter().zip(&gm.data) {
                assert_eq!(p.to_bits(), q.to_bits(),
                           "v3 round-trip must be bit-identical");
            }
        }
    }

    #[test]
    fn v3_checkpoint_roundtrips_lora_and_rosa() {
        let spec = test_spec(2);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        for (name, method) in
            [("lo", Method::LoRA), ("ro", Method::RoSA)]
        {
            model
                .insert_synthetic_method(name, 42, 2.0, method)
                .unwrap();
            let ck = model.checkpoint(name, "tiny-lm").unwrap();
            assert_eq!(ck.version, FORMAT_VERSION);
            assert!(ck.sites.iter()
                        .all(|s| s.method == method.name()));

            let xs = site_inputs(&spec, 4, 9);
            let want = model.forward(name, &xs).unwrap();
            let mut fresh =
                AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
            fresh.load_checkpoint(name, &ck, 2.0).unwrap();
            assert_eq!(fresh.get(name).unwrap().method, method);
            let got = fresh.forward(name, &xs).unwrap();
            for (wm, gm) in want.iter().zip(&got) {
                for (p, q) in wm.data.iter().zip(&gm.data) {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "{} round-trip must be bit-identical",
                               method.name());
                }
            }
        }
    }

    #[test]
    fn v2_load_rejects_mismatched_and_missing_site_blocks() {
        let spec = test_spec(2);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut model, "a", 7);
        let ck = model.checkpoint("a", "tiny-lm_cosa").unwrap();

        // wrong site dims in the block
        let mut bad = ck.clone();
        bad.sites[0].m += 1;
        let mut fresh = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        assert!(fresh.load_checkpoint("a", &bad, 2.0).is_err());

        // site block present but core tensor missing
        let mut bad = ck.clone();
        bad.tensors.remove("site00.y");
        assert!(fresh.load_checkpoint("a", &bad, 2.0).is_err());

        // a spec site entirely absent from the checkpoint
        let mut bad = ck.clone();
        bad.sites.remove(1);
        bad.tensors.remove("site01.y");
        assert!(fresh.load_checkpoint("a", &bad, 2.0).is_err());

        // an unknown per-site method tag is refused
        let mut bad = ck.clone();
        bad.sites[0].method = "qlora".into();
        assert!(fresh.load_checkpoint("a", &bad, 2.0).is_err());
    }

    #[test]
    fn v1_checkpoint_loads_as_single_site_model() {
        // A PR-3-era file: no version/sites metadata, one core tensor.
        let mut tensors = BTreeMap::new();
        let mut rng = Pcg64::new(4);
        let y = Matrix::gaussian(4, 3, 0.5, &mut rng);
        tensors.insert("adp.0.wq.y".to_string(),
                       (vec![4usize, 3], y.data.clone()));
        let ck = Checkpoint {
            version: 1,
            method: "cosa".into(),
            adapter_seed: 77,
            artifact: "tiny-lm_cosa".into(),
            step: 5,
            sites: Vec::new(),
            tensors,
        };
        let mut model = AdaptedModel::single_site(
            "adp.0.wq", SiteShape { m: 12, n: 10 }, 4, 3, 1 << 20);
        model.load_checkpoint("mathbot", &ck, 2.0).unwrap();
        let x = Matrix::gaussian(2, 10, 1.0, &mut rng);
        let got = model.forward_one("mathbot", &x).unwrap();
        // projections derive from the *tensor* stem, not the spec name
        let l = regen_l(77, "adp.0.wq.l", 12, 4);
        let r = regen_r(77, "adp.0.wq.r", 3, 10);
        let want = adapter_forward(&x, &l, &r, &y, 2.0);
        assert_eq!(got, want, "v1 stem-derived projections must be used");

        // a multi-site model refuses a core-count mismatch
        let mut multi = AdaptedModel::new(test_spec(2), 1 << 20).unwrap();
        assert!(multi.load_checkpoint("mathbot", &ck, 2.0).is_err());
    }

    #[test]
    fn quantized_cache_serves_within_codec_tolerance_and_accounts_bytes() {
        // The install-time quantization path: same adapter, same
        // inputs, bf16/int8 cache codecs — outputs stay within each
        // codec's error budget of the f32 serving path, and every
        // resident byte is accounted under the right codec at its
        // encoded (not f32) size.
        let spec = test_spec(2);
        let xs = site_inputs(&spec, 4, 13);
        let mut f32_model =
            AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        add_adapter(&mut f32_model, "a", 7);
        let want = f32_model.forward("a", &xs).unwrap();
        assert_eq!(f32_model.cache_quant(), QuantKind::F32);
        let by = f32_model.cache_bytes_by_kind();
        assert_eq!(by[0], f32_model.cache_bytes());
        assert_eq!(by[1] + by[2], 0);

        for (kind, tol) in
            [(QuantKind::Bf16, 0.05f32), (QuantKind::Int8, 0.15f32)]
        {
            let mut model =
                AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
            model.set_cache_quant(kind);
            add_adapter(&mut model, "a", 7);
            let got = model.forward("a", &xs).unwrap();
            for (s, (gm, wm)) in got.iter().zip(&want).enumerate() {
                let rel = gm.sub(wm).frobenius()
                    / wm.frobenius().max(1e-12);
                assert!(rel < tol,
                        "{kind:?} site {s}: rel err {rel} over {tol}");
                assert!(rel > 0.0,
                        "{kind:?} site {s}: quantization must perturb");
            }
            // resident bytes are encoded-size exact, under one codec
            let expect: usize = spec
                .sites
                .iter()
                .map(|s| {
                    kind.bytes_for(s.shape.m, s.a)
                        + kind.bytes_for(s.b, s.shape.n)
                })
                .sum();
            assert_eq!(model.cache_bytes(), expect);
            let by = model.cache_bytes_by_kind();
            let slot = match kind {
                QuantKind::F32 => 0,
                QuantKind::Bf16 => 1,
                QuantKind::Int8 => 2,
            };
            assert_eq!(by[slot], expect);
            assert_eq!(by.iter().sum::<usize>(), expect);
        }
    }

    #[test]
    fn grouped_forward_with_quantized_cache_matches_per_adapter_calls() {
        // The fused path through quantized-source packs must stay
        // bit-identical to slicing the rows apart and composing
        // per-adapter forwards — the f32 guarantee, under int8.
        let spec = test_spec(2);
        let mut model = AdaptedModel::new(spec.clone(), 1 << 20).unwrap();
        model.set_cache_quant(QuantKind::Int8);
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            add_adapter(&mut model, name, 7 + i as u64);
        }
        let names = ["a", "b", "c"];
        let segs = [2usize, 0, 3];
        let total: usize = segs.iter().sum();
        let xs = site_inputs(&spec, total, 21);
        let mut ws = Workspace::new();
        let mut outs: Vec<Matrix> = spec
            .sites
            .iter()
            .map(|s| Matrix::zeros(total, s.shape.m))
            .collect();
        model
            .forward_grouped_into(&names, &segs, &xs, &mut ws, &mut outs)
            .unwrap();
        let mut row = 0usize;
        for (g, &rows) in segs.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let sub_xs: Vec<Matrix> = xs
                .iter()
                .map(|x| Matrix::from_vec(
                    rows,
                    x.cols,
                    x.data[row * x.cols..(row + rows) * x.cols].to_vec(),
                ))
                .collect();
            let mut sub_outs: Vec<Matrix> = spec
                .sites
                .iter()
                .map(|s| Matrix::zeros(rows, s.shape.m))
                .collect();
            model
                .forward_into(names[g], &sub_xs, &mut ws, &mut sub_outs)
                .unwrap();
            for (s, so) in sub_outs.iter().enumerate() {
                let m = spec.sites[s].shape.m;
                let fused = &outs[s].data[row * m..(row + rows) * m];
                for (p, q) in fused.iter().zip(&so.data) {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "adapter {g} site {s} diverged under int8");
                }
            }
            row += rows;
        }
    }

    #[test]
    fn forward_one_requires_single_site() {
        let mut model = AdaptedModel::new(test_spec(2), 1 << 20).unwrap();
        add_adapter(&mut model, "a", 7);
        let x = Matrix::zeros(1, 10);
        assert!(model.forward_one("a", &x).is_err());
    }
}
