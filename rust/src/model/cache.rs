//! `ProjectionCache` — byte-budgeted LRU over regenerated `L`/`R`
//! projections, shared by **every site** of an [`AdaptedModel`].
//!
//! Regeneration is O(m·a + b·n) gaussian draws — cheap enough to redo,
//! expensive enough to cache.  The cache is keyed by
//! `(seed, tensor name, rows, cols)`: the tensor name embeds the site
//! stem (`adp.0.wq.l`), so one budget arbitrates residency across all
//! sites of all adapters — a hot adapter keeps its whole per-model
//! projection set warm while a cold site's entries age out, instead of
//! every site hoarding a fixed slice of the budget (the per-site-cache
//! baseline `serve::bench::run_model` measures against).  Hits bump a
//! logical clock, misses regenerate and insert, and inserts evict
//! least-recently-used entries until the budget holds (the newest entry
//! is always kept resident so a single over-budget projection still
//! serves).  Entries are `Arc<Matrix>` so scheduler workers can hold a
//! projection across a batch while the cache concurrently evicts it for
//! someone else.
//!
//! [`AdaptedModel`]: crate::model::AdaptedModel

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::math::matrix::Matrix;

/// Cache key: (seed, tensor name, rows, cols).  Dims are part of the
/// identity so two adapters sharing a seed but differing in core shape
/// can never collide; the tensor name carries the site stem, so two
/// sites of one adapter never collide either.
pub type CacheKey = (u64, String, usize, usize);

struct CacheEntry {
    mat: Arc<Matrix>,
    last_used: u64,
}

/// Counters exposed for benches and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Byte-budgeted LRU over regenerated projections (see module docs).
///
/// Recency is indexed (`order`: last-used tick → key, ticks unique), so
/// an eviction is O(log n) instead of a full scan — a *shared* cache
/// fronting every site of a model holds thousands of entries, and an
/// O(n) victim scan per eviction would tax precisely the configuration
/// this layer exists to make cheap.
pub struct ProjectionCache {
    budget_bytes: usize,
    bytes: usize,
    tick: u64,
    entries: HashMap<CacheKey, CacheEntry>,
    /// last-used tick → key; in lockstep with `entries`.
    order: BTreeMap<u64, CacheKey>,
    stats: CacheStats,
}

fn mat_bytes(m: &Matrix) -> usize {
    m.data.len() * std::mem::size_of::<f32>()
}

impl ProjectionCache {
    pub fn new(budget_bytes: usize) -> ProjectionCache {
        ProjectionCache {
            budget_bytes,
            bytes: 0,
            tick: 0,
            entries: HashMap::new(),
            order: BTreeMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Bytes currently resident per the incremental accounting
    /// (diagnostic).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Bytes currently resident recomputed from the entries themselves —
    /// must always equal [`ProjectionCache::bytes`]; the cross-site
    /// accounting tests assert it after eviction churn so one site's
    /// evictions can never corrupt the ledger another site's inserts
    /// depend on.
    pub fn recomputed_bytes(&self) -> usize {
        self.entries.values().map(|e| mat_bytes(&e.mat)).sum()
    }

    /// Entries currently resident (diagnostic).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Hit-only lookup: bumps recency and the hit counter on a hit,
    /// touches nothing on a miss (the caller is expected to regenerate
    /// outside any lock and come back through [`ProjectionCache::get_or`]).
    pub fn peek(&mut self, key: &CacheKey) -> Option<Arc<Matrix>> {
        if let Some(e) = self.entries.get_mut(key) {
            self.tick += 1;
            self.order.remove(&e.last_used);
            e.last_used = self.tick;
            self.order.insert(self.tick, key.clone());
            self.stats.hits += 1;
            return Some(e.mat.clone());
        }
        None
    }

    /// The cached projection for `key`, regenerating via `regen` on a
    /// miss.  Hits refresh recency; misses insert and then evict
    /// least-recently-used entries until the budget holds (the entry
    /// just inserted is never the victim).
    pub fn get_or(
        &mut self,
        key: CacheKey,
        regen: impl FnOnce() -> Matrix,
    ) -> Arc<Matrix> {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            self.order.remove(&e.last_used);
            e.last_used = self.tick;
            self.order.insert(self.tick, key);
            self.stats.hits += 1;
            return e.mat.clone();
        }
        self.stats.misses += 1;
        let mat = Arc::new(regen());
        self.bytes += mat_bytes(&mat);
        let entry = CacheEntry { mat: mat.clone(), last_used: self.tick };
        self.entries.insert(key.clone(), entry);
        self.order.insert(self.tick, key.clone());
        self.evict_to_budget(&key);
        debug_assert_eq!(self.order.len(), self.entries.len(),
                         "recency index out of lockstep");
        mat
    }

    fn evict_to_budget(&mut self, keep: &CacheKey) {
        while self.bytes > self.budget_bytes && self.entries.len() > 1 {
            // Oldest tick whose key is not the just-inserted one — the
            // index is ordered, so this inspects at most two entries.
            let victim = self
                .order
                .iter()
                .find(|(_, k)| *k != keep)
                .map(|(t, k)| (*t, k.clone()));
            let Some((t, k)) = victim else { break };
            self.order.remove(&t);
            if let Some(e) = self.entries.remove(&k) {
                self.bytes -= mat_bytes(&e.mat);
                self.stats.evictions += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, v: f32) -> Matrix {
        Matrix::from_vec(rows, cols, vec![v; rows * cols])
    }

    #[test]
    fn hit_miss_and_budget_eviction() {
        // budget fits exactly one 10-float entry (40 bytes)
        let mut c = ProjectionCache::new(40);
        let k1: CacheKey = (1, "a.l".into(), 2, 5);
        let k2: CacheKey = (1, "b.l".into(), 2, 5);
        let m1 = c.get_or(k1.clone(), || mat(2, 5, 1.0));
        assert_eq!(c.stats().misses, 1);
        assert!(Arc::ptr_eq(&m1, &c.get_or(k1.clone(), || mat(2, 5, 9.0))));
        assert_eq!(c.stats().hits, 1);
        c.get_or(k2.clone(), || mat(2, 5, 2.0));
        assert_eq!(c.stats().evictions, 1, "k1 evicted for k2");
        assert!(c.peek(&k1).is_none());
        assert!(c.peek(&k2).is_some());
    }

    #[test]
    fn byte_ledger_survives_mixed_size_churn() {
        // Heterogeneous entry sizes (two "sites") churning under a tight
        // budget: the incremental ledger must equal the recomputed sum
        // at every step — an eviction of one site's entries never
        // corrupts the accounting the other site's inserts rely on.
        let mut c = ProjectionCache::new(100);
        for i in 0..40u64 {
            let (rows, cols) = if i % 2 == 0 { (3, 4) } else { (1, 7) };
            let key: CacheKey = (i % 5, format!("site{}.l", i % 3), rows, cols);
            c.get_or(key, || mat(rows, cols, i as f32));
            assert_eq!(c.bytes(), c.recomputed_bytes(), "ledger drift at {i}");
            assert!(c.bytes() <= 100 || c.len() == 1, "over budget at {i}");
        }
        assert!(c.stats().evictions > 0, "churn must actually evict");
    }

    #[test]
    fn zero_budget_keeps_only_newest() {
        let mut c = ProjectionCache::new(0);
        c.get_or((1, "x".into(), 1, 1), || mat(1, 1, 1.0));
        c.get_or((2, "y".into(), 1, 1), || mat(1, 1, 2.0));
        assert_eq!(c.len(), 1, "newest entry always resident");
        assert_eq!(c.bytes(), c.recomputed_bytes());
    }
}
