//! `ProjectionCache` — byte-budgeted LRU over regenerated `L`/`R`
//! projections, shared by **every site** of an [`AdaptedModel`].
//!
//! Regeneration is O(m·a + b·n) gaussian draws — cheap enough to redo,
//! expensive enough to cache.  The cache is keyed by
//! `(seed, tensor name, rows, cols)`: the tensor name embeds the site
//! stem (`adp.0.wq.l`), so one budget arbitrates residency across all
//! sites of all adapters — a hot adapter keeps its whole per-model
//! projection set warm while a cold site's entries age out, instead of
//! every site hoarding a fixed slice of the budget (the per-site-cache
//! baseline `serve::bench::run_model` measures against).  Hits bump a
//! logical clock, misses regenerate and insert, and inserts evict
//! least-recently-used entries until the budget holds (the newest entry
//! is always kept resident so a single over-budget projection still
//! serves).  Entries are `Arc<QuantMat>` so scheduler workers can hold
//! a projection across a batch while the cache concurrently evicts it
//! for someone else — and so residents can live in bf16 or int8
//! storage ([`QuantKind`]) at half or quarter the f32 footprint.  The
//! byte ledger counts *encoded* bytes, so a quantized cache holds
//! proportionally more projections at the same budget; the model layer
//! decides the kind at install time (`[serve] cache_quant`).
//!
//! [`AdaptedModel`]: crate::model::AdaptedModel

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::linalg::{QuantKind, QuantMat};

/// Cache key: (seed, tensor name, rows, cols).  Dims are part of the
/// identity so two adapters sharing a seed but differing in core shape
/// can never collide; the tensor name carries the site stem, so two
/// sites of one adapter never collide either.
pub type CacheKey = (u64, String, usize, usize);

struct CacheEntry {
    mat: Arc<QuantMat>,
    last_used: u64,
}

/// Counters exposed for benches and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Byte-budgeted LRU over regenerated projections (see module docs).
///
/// Recency is indexed (`order`: last-used tick → key, ticks unique), so
/// an eviction is O(log n) instead of a full scan — a *shared* cache
/// fronting every site of a model holds thousands of entries, and an
/// O(n) victim scan per eviction would tax precisely the configuration
/// this layer exists to make cheap.
pub struct ProjectionCache {
    budget_bytes: usize,
    bytes: usize,
    tick: u64,
    entries: HashMap<CacheKey, CacheEntry>,
    /// last-used tick → key; in lockstep with `entries`.
    order: BTreeMap<u64, CacheKey>,
    stats: CacheStats,
}


impl ProjectionCache {
    pub fn new(budget_bytes: usize) -> ProjectionCache {
        ProjectionCache {
            budget_bytes,
            bytes: 0,
            tick: 0,
            entries: HashMap::new(),
            order: BTreeMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Bytes currently resident per the incremental accounting
    /// (diagnostic).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Bytes currently resident recomputed from the entries themselves —
    /// must always equal [`ProjectionCache::bytes`]; the cross-site
    /// accounting tests assert it after eviction churn so one site's
    /// evictions can never corrupt the ledger another site's inserts
    /// depend on.
    pub fn recomputed_bytes(&self) -> usize {
        self.entries.values().map(|e| e.mat.bytes()).sum()
    }

    /// Resident bytes broken down by storage kind, in
    /// `[f32, bf16, int8]` order — the `/v1/stats` capacity view.  The
    /// three components always sum to [`ProjectionCache::bytes`].
    pub fn resident_bytes_by_kind(&self) -> [usize; 3] {
        let mut by = [0usize; 3];
        for e in self.entries.values() {
            let slot = match e.mat.kind() {
                QuantKind::F32 => 0,
                QuantKind::Bf16 => 1,
                QuantKind::Int8 => 2,
            };
            by[slot] += e.mat.bytes();
        }
        by
    }

    /// Entries currently resident (diagnostic).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Hit-only lookup: bumps recency and the hit counter on a hit,
    /// touches nothing on a miss (the caller is expected to regenerate
    /// outside any lock and come back through [`ProjectionCache::get_or`]).
    pub fn peek(&mut self, key: &CacheKey) -> Option<Arc<QuantMat>> {
        if let Some(e) = self.entries.get_mut(key) {
            self.tick += 1;
            self.order.remove(&e.last_used);
            e.last_used = self.tick;
            self.order.insert(self.tick, key.clone());
            self.stats.hits += 1;
            return Some(e.mat.clone());
        }
        None
    }

    /// The cached projection for `key`, regenerating via `regen` on a
    /// miss.  Hits refresh recency; misses insert and then evict
    /// least-recently-used entries until the budget holds (the entry
    /// just inserted is never the victim).  `regen` returns an
    /// already-encoded [`QuantMat`] — the caller picks the storage
    /// kind, the cache only meters encoded bytes.
    pub fn get_or(
        &mut self,
        key: CacheKey,
        regen: impl FnOnce() -> QuantMat,
    ) -> Arc<QuantMat> {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            self.order.remove(&e.last_used);
            e.last_used = self.tick;
            self.order.insert(self.tick, key);
            self.stats.hits += 1;
            return e.mat.clone();
        }
        self.stats.misses += 1;
        let mat = Arc::new(regen());
        self.bytes += mat.bytes();
        let entry = CacheEntry { mat: mat.clone(), last_used: self.tick };
        self.entries.insert(key.clone(), entry);
        self.order.insert(self.tick, key.clone());
        self.evict_to_budget(&key);
        debug_assert_eq!(self.order.len(), self.entries.len(),
                         "recency index out of lockstep");
        mat
    }

    fn evict_to_budget(&mut self, keep: &CacheKey) {
        while self.bytes > self.budget_bytes && self.entries.len() > 1 {
            // Oldest tick whose key is not the just-inserted one — the
            // index is ordered, so this inspects at most two entries.
            let victim = self
                .order
                .iter()
                .find(|(_, k)| *k != keep)
                .map(|(t, k)| (*t, k.clone()));
            let Some((t, k)) = victim else { break };
            self.order.remove(&t);
            if let Some(e) = self.entries.remove(&k) {
                self.bytes -= e.mat.bytes();
                self.stats.evictions += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::matrix::Matrix;

    fn mat(rows: usize, cols: usize, v: f32) -> QuantMat {
        let m = Matrix::from_vec(rows, cols, vec![v; rows * cols]);
        QuantMat::encode_owned(m, QuantKind::F32)
    }

    fn qmat(rows: usize, cols: usize, v: f32, kind: QuantKind) -> QuantMat {
        let m = Matrix::from_vec(rows, cols, vec![v; rows * cols]);
        QuantMat::encode_owned(m, kind)
    }

    #[test]
    fn hit_miss_and_budget_eviction() {
        // budget fits exactly one 10-float entry (40 bytes)
        let mut c = ProjectionCache::new(40);
        let k1: CacheKey = (1, "a.l".into(), 2, 5);
        let k2: CacheKey = (1, "b.l".into(), 2, 5);
        let m1 = c.get_or(k1.clone(), || mat(2, 5, 1.0));
        assert_eq!(c.stats().misses, 1);
        assert!(Arc::ptr_eq(&m1, &c.get_or(k1.clone(), || mat(2, 5, 9.0))));
        assert_eq!(c.stats().hits, 1);
        c.get_or(k2.clone(), || mat(2, 5, 2.0));
        assert_eq!(c.stats().evictions, 1, "k1 evicted for k2");
        assert!(c.peek(&k1).is_none());
        assert!(c.peek(&k2).is_some());
    }

    #[test]
    fn byte_ledger_survives_mixed_size_churn() {
        // Heterogeneous entry sizes (two "sites") churning under a tight
        // budget: the incremental ledger must equal the recomputed sum
        // at every step — an eviction of one site's entries never
        // corrupts the accounting the other site's inserts rely on.
        let mut c = ProjectionCache::new(100);
        for i in 0..40u64 {
            let (rows, cols) = if i % 2 == 0 { (3, 4) } else { (1, 7) };
            let key: CacheKey = (i % 5, format!("site{}.l", i % 3), rows, cols);
            c.get_or(key, || mat(rows, cols, i as f32));
            assert_eq!(c.bytes(), c.recomputed_bytes(), "ledger drift at {i}");
            assert!(c.bytes() <= 100 || c.len() == 1, "over budget at {i}");
        }
        assert!(c.stats().evictions > 0, "churn must actually evict");
    }

    #[test]
    fn ledger_is_exact_under_mixed_quant_kind_residents() {
        // f32, bf16 and int8 residents churning in one cache: the
        // incremental ledger, the recomputed sum, and the per-kind
        // breakdown must agree at every step — quantized entries meter
        // their *encoded* bytes, not a hypothetical f32 footprint.
        let kinds = [QuantKind::F32, QuantKind::Bf16, QuantKind::Int8];
        let mut c = ProjectionCache::new(300);
        for i in 0..60u64 {
            let kind = kinds[(i % 3) as usize];
            let (rows, cols) = if i % 2 == 0 { (4, 6) } else { (2, 9) };
            let key: CacheKey =
                (i % 7, format!("s{}.{}", i % 4, kind.name()), rows, cols);
            let got = c.get_or(key, || qmat(rows, cols, i as f32, kind));
            assert_eq!(got.kind(), kind, "kind survives residency at {i}");
            assert_eq!(
                got.bytes(),
                kind.bytes_for(rows, cols),
                "encoded size at {i}"
            );
            assert_eq!(c.bytes(), c.recomputed_bytes(), "ledger drift at {i}");
            let by = c.resident_bytes_by_kind();
            assert_eq!(
                by.iter().sum::<usize>(),
                c.bytes(),
                "per-kind breakdown must sum to the ledger at {i}"
            );
        }
        assert!(c.stats().evictions > 0, "churn must actually evict");
        let by = c.resident_bytes_by_kind();
        assert!(
            by.iter().filter(|&&b| b > 0).count() >= 2,
            "mixed-kind churn should leave more than one kind resident"
        );
    }

    #[test]
    fn quantized_residents_multiply_capacity_at_equal_budget() {
        // At one fixed byte budget, bf16 entries of the same shape are
        // half the f32 footprint, so twice as many stay resident — the
        // capacity mechanism scenario 7 gates end to end.
        let count_resident = |kind: QuantKind| -> usize {
            let mut c = ProjectionCache::new(8 * 6 * 4 * 4); // four f32 8x6 panels
            for i in 0..16u64 {
                c.get_or((i, "p.l".into(), 8, 6), || qmat(8, 6, 1.0, kind));
            }
            c.len()
        };
        assert_eq!(count_resident(QuantKind::F32), 4);
        assert_eq!(count_resident(QuantKind::Bf16), 8);
        assert!(count_resident(QuantKind::Int8) > 8);
    }

    #[test]
    fn zero_budget_keeps_only_newest() {
        let mut c = ProjectionCache::new(0);
        c.get_or((1, "x".into(), 1, 1), || mat(1, 1, 1.0));
        c.get_or((2, "y".into(), 1, 1), || mat(1, 1, 2.0));
        assert_eq!(c.len(), 1, "newest entry always resident");
        assert_eq!(c.bytes(), c.recomputed_bytes());
    }
}
