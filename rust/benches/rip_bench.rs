//! Bench: RIP estimator hot path (Table 4's compute) — per-sample cost of
//! the rank-one Gram expansion across sparsity levels and configs, plus
//! coherence factorization cost.

use cosa::rip::coherence::kron_coherence;
use cosa::rip::estimator::{rip_constant, RipSetup};
use cosa::util::bench::{bench, black_box};

fn main() {
    println!("== rip_bench: Monte-Carlo RIP estimation ==");
    for (a, b) in [(32, 8), (128, 32), (256, 64)] {
        for s in [5, 20] {
            let setup = RipSetup::paper(a, b);
            let r = bench(
                &format!("rip_constant a={a} b={b} s={s} N=200"),
                300,
                || {
                    black_box(rip_constant(setup, s, 200, 42));
                },
            );
            r.throughput(200.0, "samples");
        }
    }
    println!("\n== coherence (factorized, never materializes mn x ab) ==");
    for (a, b) in [(64, 16), (256, 64)] {
        bench(&format!("kron_coherence a={a} b={b}"), 300, || {
            black_box(kron_coherence(512, 256, a, b, 7));
        });
    }
}
