//! Bench: multi-adapter serving throughput and latency — the CI-gated
//! `serving`, `serving_model`, `serving_wire`, `serving_tail`,
//! `serving_methods`, `serving_quant`, and `serving_obs` sections of
//! `BENCH_linalg.json`.
//!
//! Eight scenarios:
//!
//! 1. **acceptance** — 64 adapters, one site, Zipf 1.1 popularity,
//!    firehose injection.  The `batched_vs_sequential` field is the
//!    acceptance metric (target 1.5x; `tools/bench_regression.py`
//!    gates on it), and the throughput / p99 rows feed the
//!    conservative `serving` floors in `BENCH_baseline.json`.
//! 2. **paced** — the same fleet at a modest arrival rate, so the
//!    latency percentiles reflect scheduling delay rather than pure
//!    queue drain.
//! 3. **model acceptance** — the whole-adapted-model scenario: 24
//!    heterogeneous sites × 64 adapters, Zipf over adapters, every
//!    request touching every site, with the projection-cache budget
//!    under the total working set.  Gated fields: throughput floor,
//!    p99 ceiling, and `shared_vs_persite` (one shared LRU must not
//!    lose to statically partitioned per-site caches).
//! 4. **wire acceptance** — the scenario-1 workload through a loopback
//!    HTTP gateway: closed-loop keep-alive clients vs the in-process
//!    engine at equal concurrency.  Gated fields: throughput floor,
//!    p99 ceiling, zero request errors, and `wire_vs_inprocess` (the
//!    HTTP + streaming-JSON edge must keep >= 0.5x the engine's
//!    closed-loop throughput).
//! 5. **tail acceptance** — 24 sites × 512 adapters at Zipf s=1.0:
//!    the identical heavy-tail stream through a fused cross-adapter
//!    server and a `fused = false` per-adapter-segment server.  Gated
//!    field: `fused_vs_per_adapter >= 1.5` (machine-independent),
//!    plus conservative throughput / p99 floors.
//! 6. **methods acceptance** — the adapter-zoo cross-method table: a
//!    mixed-method 24-site model (CoSA + RoSA + LoRA fleets side by
//!    side in one engine), per-method Zipf streams plus a mixed
//!    stream whose fused batches interleave methods.  Gated field per
//!    row: `batched_vs_sequential >= 1.2` (machine-independent), plus
//!    conservative CoSA floors carried over unchanged.
//! 7. **quant acceptance** — the scenario-3 fleet (24 sites × 64
//!    adapters, Zipf 1.1) served at a deliberately thrashing LRU
//!    budget three times: f32, bf16, and int8 cache codecs.  Gated
//!    fields, all machine-independent: bf16 `capacity_vs_f32 >= 1.8`
//!    (quantized residents must nearly double effective cache
//!    capacity at the identical byte budget) and per-codec
//!    `rmse_vs_f32` bounds (bf16 <= 0.03, int8 <= 0.08) — the output
//!    error each codec pays relative to bit-exact f32 serving.
//! 8. **obs acceptance** — the telemetry-overhead scenario: the
//!    scenario-1 fleet driven twice on one identical Zipf stream, once
//!    through an untraced server and once through a server with the
//!    full `obs` registry attached (stage spans, histograms, slow
//!    ring).  Gated field: `traced_vs_untraced >= 0.95`
//!    (machine-independent ratio — tracing must cost < 5% throughput),
//!    plus a conservative traced-throughput floor.
//!
//! Knobs come from the default `[serve]` / `[model]` / `[wire]`
//! tables; `COSA_SERVE_*` / `COSA_MODEL_*` / `COSA_WIRE_*` env
//! overrides apply (so a pinned CI runner can pin workers or shrink
//! the fleet).

use cosa::config::{ModelConfig, WireConfig};
use cosa::serve::bench::{
    run, run_methods, run_model, run_obs, run_quant, run_tail,
    MethodsBenchOpts, ModelBenchOpts, ObsBenchOpts, QuantBenchOpts,
    ServeBenchOpts, TailBenchOpts,
};
use cosa::util::bench::write_bench_json;
use cosa::util::json::Json;
use cosa::wire::bench::{run_wire, WireBenchOpts};

fn main() {
    println!("== serve_bench: multi-adapter serving engine ==");
    let mut rows: Vec<Json> = Vec::new();

    // Scenario 1: the acceptance workload (64 adapters, Zipf 1.1).
    let acceptance = ServeBenchOpts {
        cfg: ServeBenchOpts::default().cfg.env_overridden(),
        ..ServeBenchOpts::default()
    };
    match run(&acceptance) {
        Ok(report) => {
            report.print();
            rows.push(report.to_json());
        }
        Err(e) => eprintln!("serve_bench acceptance scenario failed: {e:#}"),
    }

    // Scenario 2: paced arrivals — latency under schedule, not drain.
    let paced = ServeBenchOpts {
        requests: 512,
        rate: 2000.0,
        ..acceptance.clone()
    };
    match run(&paced) {
        Ok(report) => {
            report.print();
            rows.push(report.to_json());
        }
        Err(e) => eprintln!("serve_bench paced scenario failed: {e:#}"),
    }

    write_bench_json("serving", Json::Arr(rows));

    // Scenario 3: the whole-model acceptance workload (24 sites x 64
    // adapters).  The spec honors COSA_MODEL_* so a pinned runner can
    // reshape it; the serve knobs reuse the scenario-1 env overrides,
    // but the cache budget stays the model default (pressure is the
    // point of the shared-vs-per-site gate).
    let mdefaults = ModelBenchOpts::default();
    let model_cfg = ModelConfig::default().env_overridden();
    let mut model_rows: Vec<Json> = Vec::new();
    match model_cfg.to_spec("serve-bench") {
        Ok(spec) => {
            let mopts = ModelBenchOpts {
                spec,
                cfg: cosa::config::ServeConfig {
                    cache_mb: mdefaults.cfg.cache_mb,
                    ..acceptance.cfg.clone()
                },
                ..mdefaults
            };
            match run_model(&mopts) {
                Ok(report) => {
                    report.print();
                    model_rows.push(report.to_json());
                }
                Err(e) => {
                    eprintln!("serve_bench model scenario failed: {e:#}")
                }
            }
        }
        Err(e) => eprintln!("serve_bench model spec invalid: {e:#}"),
    }
    write_bench_json("serving_model", Json::Arr(model_rows));

    // Scenario 4: the wire acceptance workload — scenario 1's fleet
    // served over a loopback HTTP gateway on an ephemeral port.  The
    // serve knobs reuse the scenario-1 env overrides; COSA_WIRE_* can
    // reshape the transport (the port is always ephemeral here).
    let wdefaults = WireBenchOpts::default();
    let wopts = WireBenchOpts {
        serve: acceptance.cfg.clone(),
        wire: WireConfig {
            port: 0,
            ..WireConfig::default().env_overridden()
        },
        ..wdefaults
    };
    let mut wire_rows: Vec<Json> = Vec::new();
    match run_wire(&wopts) {
        Ok(report) => {
            report.print();
            wire_rows.push(report.to_json());
        }
        Err(e) => eprintln!("serve_bench wire scenario failed: {e:#}"),
    }
    write_bench_json("serving_wire", Json::Arr(wire_rows));

    // Scenario 5: the tail acceptance workload — fused cross-adapter
    // batching vs per-adapter-segment batching on the identical Zipf
    // s=1.0 stream over 512 adapters.  Batch/wait knobs come from the
    // TailBenchOpts defaults (the fleet shape is the scenario), but
    // COSA_SERVE_WORKERS still applies through env_overridden so a
    // pinned runner can fix parallelism.
    let tdefaults = TailBenchOpts::default();
    let topts = TailBenchOpts {
        cfg: cosa::config::ServeConfig {
            workers: acceptance.cfg.workers,
            ..tdefaults.cfg.clone()
        },
        ..tdefaults
    };
    let mut tail_rows: Vec<Json> = Vec::new();
    match run_tail(&topts) {
        Ok(report) => {
            report.print();
            tail_rows.push(report.to_json());
        }
        Err(e) => eprintln!("serve_bench tail scenario failed: {e:#}"),
    }
    write_bench_json("serving_tail", Json::Arr(tail_rows));

    // Scenario 6: the cross-method acceptance workload — CoSA, RoSA,
    // and LoRA fleets in one mixed-method model, per-method streams
    // plus a method-interleaved mixed stream.  The serve knobs reuse
    // the scenario-1 env overrides; the fleet shape is the scenario.
    let medefaults = MethodsBenchOpts::default();
    let meopts = MethodsBenchOpts {
        cfg: cosa::config::ServeConfig {
            cache_mb: medefaults.cfg.cache_mb,
            ..acceptance.cfg.clone()
        },
        ..medefaults
    };
    let mut method_rows: Vec<Json> = Vec::new();
    match run_methods(&meopts) {
        Ok(report) => {
            report.print();
            method_rows.extend(report.to_json_rows());
        }
        Err(e) => eprintln!("serve_bench methods scenario failed: {e:#}"),
    }
    write_bench_json("serving_methods", Json::Arr(method_rows));

    // Scenario 7: the quantized-cache acceptance workload — the
    // scenario-3 fleet driven three times at one thrashing LRU budget,
    // once per cache codec.  The fleet shape and cache budget ARE the
    // scenario (QuantBenchOpts defaults); only the worker override
    // carries over so a pinned runner can fix parallelism.  The gated
    // fields (capacity_vs_f32, rmse_vs_f32) are exact counts and
    // deterministic arithmetic — machine-independent by construction.
    let qdefaults = QuantBenchOpts::default();
    let qopts = QuantBenchOpts {
        cfg: cosa::config::ServeConfig {
            workers: acceptance.cfg.workers,
            ..qdefaults.cfg.clone()
        },
        ..qdefaults
    };
    let mut quant_rows: Vec<Json> = Vec::new();
    match run_quant(&qopts) {
        Ok(report) => {
            report.print();
            quant_rows.extend(report.to_json_rows());
        }
        Err(e) => eprintln!("serve_bench quant scenario failed: {e:#}"),
    }
    write_bench_json("serving_quant", Json::Arr(quant_rows));

    // Scenario 8: the telemetry-overhead acceptance workload — the
    // scenario-1 fleet on one identical stream, untraced vs traced.
    // The serve knobs reuse the scenario-1 env overrides so both
    // servers and the engine the `serving` floors were measured on
    // share a configuration; the gated `traced_vs_untraced` ratio is
    // machine-independent (same machine, same stream, both halves).
    let odefaults = ObsBenchOpts::default();
    let oopts = ObsBenchOpts {
        cfg: acceptance.cfg.clone(),
        ..odefaults
    };
    let mut obs_rows: Vec<Json> = Vec::new();
    match run_obs(&oopts) {
        Ok(report) => {
            report.print();
            obs_rows.push(report.to_json());
        }
        Err(e) => eprintln!("serve_bench obs scenario failed: {e:#}"),
    }
    write_bench_json("serving_obs", Json::Arr(obs_rows));
}
