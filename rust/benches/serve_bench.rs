//! Bench: multi-adapter serving throughput and latency — the CI-gated
//! `serving` section of `BENCH_linalg.json`.
//!
//! Two scenarios:
//!
//! 1. **acceptance** — 64 adapters, Zipf 1.1 popularity, firehose
//!    injection.  The `batched_vs_sequential` field is the acceptance
//!    metric (target 1.5x; `tools/bench_regression.py` gates on it),
//!    and the throughput / p99 rows feed the conservative `serving`
//!    floors in `BENCH_baseline.json`.
//! 2. **paced** — the same fleet at a modest arrival rate, so the
//!    latency percentiles reflect scheduling delay rather than pure
//!    queue drain.
//!
//! Knobs come from the default `[serve]` table; `COSA_SERVE_*` env
//! overrides apply (so a pinned CI runner can pin workers).

use cosa::serve::bench::{run, ServeBenchOpts};
use cosa::util::bench::write_bench_json;
use cosa::util::json::Json;

fn main() {
    println!("== serve_bench: multi-adapter serving engine ==");
    let mut rows: Vec<Json> = Vec::new();

    // Scenario 1: the acceptance workload (64 adapters, Zipf 1.1).
    let acceptance = ServeBenchOpts {
        cfg: ServeBenchOpts::default().cfg.env_overridden(),
        ..ServeBenchOpts::default()
    };
    match run(&acceptance) {
        Ok(report) => {
            report.print();
            rows.push(report.to_json());
        }
        Err(e) => eprintln!("serve_bench acceptance scenario failed: {e:#}"),
    }

    // Scenario 2: paced arrivals — latency under schedule, not drain.
    let paced = ServeBenchOpts {
        requests: 512,
        rate: 2000.0,
        ..acceptance.clone()
    };
    match run(&paced) {
        Ok(report) => {
            report.print();
            rows.push(report.to_json());
        }
        Err(e) => eprintln!("serve_bench paced scenario failed: {e:#}"),
    }

    write_bench_json("serving", Json::Arr(rows));
}
