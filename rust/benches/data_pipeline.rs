//! Bench: synthetic-data generators and the batcher — the L3 data path
//! must stay far below the XLA step cost (EXPERIMENTS.md §Perf L3).

use cosa::data::batcher::{lm_batch, Batcher};
use cosa::data::{codegen, mathgen, nlu};
use cosa::util::bench::{bench, black_box};

fn main() {
    println!("== data_pipeline ==");
    bench("mathgen 512 examples (mixed)", 300, || {
        black_box(mathgen::generate(mathgen::Family::Mixed, 512, 0, 64, 1));
    });
    bench("codegen 512 examples", 300, || {
        black_box(codegen::generate(512, 0, 64, 1));
    });
    bench("nlu mrpc-sim 512 examples", 300, || {
        black_box(nlu::generate("mrpc-sim", 512, 0, 512, 48, 1).unwrap());
    });

    let ds = mathgen::generate(mathgen::Family::Mixed, 4096, 0, 64, 2);
    let mut batcher = Batcher::new(ds.train.len(), 8, 3);
    let r = bench("batcher next + lm_batch (B=8, T=64)", 300, || {
        let idx = batcher.next_indices();
        let exs: Vec<&_> = idx.iter().map(|i| &ds.train[*i]).collect();
        black_box(lm_batch(&exs, 8, 64));
    });
    r.throughput(8.0, "examples");
}
