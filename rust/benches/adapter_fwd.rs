//! Bench: host-side CoSA adapter forward vs materialized ΔW — the
//! paper's Table 1 FWD complexity argument in wall-clock form, plus the
//! projection-regeneration cost behind the seed-storage trick.
//!
//! Runs every shape against each `linalg` backend, reports GFLOP/s, and
//! emits a machine-readable `BENCH_linalg.json` section (merged with the
//! sections other benches write) so old-vs-new is diffable.

use cosa::adapters::cosa::{adapter_forward, adapter_forward_into,
                           materialize_delta, regen_l, regen_r};
use cosa::linalg::{self, Kind, Workspace};
use cosa::math::matrix::Matrix;
use cosa::math::rng::Pcg64;
use cosa::util::bench::{bench, black_box, write_bench_json};
use cosa::util::json::{obj, Json};

/// The backend that actually executes (the COSA_BACKEND env override
/// silently wins over `set_backend`, and `auto` resolves via
/// `linalg::resolved_kind`).
fn effective_backend() -> &'static str {
    linalg::resolved_kind().name()
}

fn main() {
    let mut rows_json: Vec<Json> = Vec::new();
    println!("== adapter_fwd: activation path, per linalg backend ==");
    // (512,…) legacy shape; (2048,2048,64,64) is the acceptance shape
    // (paper-scale site, a=b≤64); (2048,2048,1024,256) the paper NLG pair
    for (m, n, a, b, rows) in [
        (512, 512, 128, 64, 64),
        (2048, 2048, 64, 64, 64),
        (2048, 2048, 1024, 256, 16),
    ] {
        let mut rng = Pcg64::new(1);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);
        let l = regen_l(7, "bench.l", m, a);
        let r = regen_r(7, "bench.r", b, n);
        let y = Matrix::gaussian(a, b, 0.02, &mut rng);
        // mul+add per chained product: x·Rᵀ, u·Yᵀ, v·Lᵀ
        let flops = 2.0 * rows as f64 * (n * b + b * a + a * m) as f64;

        for kind in [Kind::Reference, Kind::Tiled, Kind::Packed] {
            linalg::set_backend(kind, 0);
            if linalg::resolved_kind() != kind {
                println!("warning: COSA_BACKEND env override is active \
                          ({}); skipping the {} pass so BENCH_linalg.json \
                          rows stay truthful", effective_backend(),
                         kind.name());
                continue;
            }
            let res = bench(
                &format!("adapter_forward[{}] m={m} n={n} a={a} b={b} \
                          rows={rows}", kind.name()),
                400,
                || {
                    black_box(adapter_forward(&x, &l, &r, &y, 2.0));
                },
            );
            res.report_gflops(flops);
            rows_json.push(obj(vec![
                ("bench", "adapter_forward".into()),
                ("backend", kind.name().into()),
                ("m", m.into()),
                ("n", n.into()),
                ("a", a.into()),
                ("b", b.into()),
                ("rows", rows.into()),
                ("mean_ns", res.mean_ns.into()),
                ("min_ns", res.min_ns.into()),
                ("gflops", res.gflops(flops).into()),
            ]));
        }

        // workspace-reused variant on the default backend (label = the
        // backend that actually runs, env override included)
        linalg::set_backend(Kind::Auto, 0);
        let mut ws = Workspace::new();
        let mut out = Matrix::zeros(rows, m);
        let eff = effective_backend();
        let res = bench(
            &format!("adapter_forward_into[{eff}] m={m} n={n} a={a} b={b}"),
            400,
            || {
                adapter_forward_into(&x, &l, &r, &y, 2.0, &mut ws,
                                     &mut out);
                black_box(out.data[0]);
            },
        );
        res.report_gflops(flops);
        println!("    workspace fresh allocs after warmup: {} (expect to \
                  stay flat)", ws.fresh_allocs());
        rows_json.push(obj(vec![
            ("bench", "adapter_forward_into".into()),
            ("backend", eff.into()),
            ("m", m.into()),
            ("n", n.into()),
            ("a", a.into()),
            ("b", b.into()),
            ("rows", rows.into()),
            ("mean_ns", res.mean_ns.into()),
            ("gflops", res.gflops(flops).into()),
            ("ws_fresh_allocs", ws.fresh_allocs().into()),
        ]));

        if m <= 512 {
            let res = bench(
                &format!("materialize ΔW + matmul m={m} n={n}"),
                400,
                || {
                    let d = materialize_delta(&l, &y, &r, 2.0);
                    black_box(x.matmul_nt(&d));
                },
            );
            rows_json.push(obj(vec![
                ("bench", "materialized_delta".into()),
                ("backend", eff.into()),
                ("m", m.into()),
                ("n", n.into()),
                ("mean_ns", res.mean_ns.into()),
            ]));
        }
    }
    linalg::set_backend(Kind::Auto, 0);

    println!("\n== projection regeneration from seed (adapter load path) ==");
    for (m, a) in [(512, 128), (2048, 1024)] {
        let res = bench(&format!("regen_l m={m} a={a}"), 300, || {
            black_box(regen_l(7, "bench.l", m, a));
        });
        rows_json.push(obj(vec![
            ("bench", "regen_l".into()),
            ("m", m.into()),
            ("a", a.into()),
            ("mean_ns", res.mean_ns.into()),
        ]));
    }

    write_bench_json("adapter_fwd", Json::Arr(rows_json));
}
