//! Bench: host-side CoSA adapter forward vs materialized ΔW — the
//! paper's Table 1 FWD complexity argument in wall-clock form, plus the
//! projection-regeneration cost behind the seed-storage trick.

use cosa::adapters::cosa::{adapter_forward, materialize_delta, regen_l,
                           regen_r};
use cosa::math::matrix::Matrix;
use cosa::math::rng::Pcg64;
use cosa::util::bench::{bench, black_box};

fn main() {
    println!("== adapter_fwd: activation path vs materialized ΔW ==");
    // paper NLG shape: site 2048x2048, (a,b)=(1024,256), batch rows 64
    for (m, n, a, b, rows) in [
        (512, 512, 128, 64, 64),
        (2048, 2048, 1024, 256, 16),
    ] {
        let mut rng = Pcg64::new(1);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);
        let l = regen_l(7, "bench.l", m, a);
        let r = regen_r(7, "bench.r", b, n);
        let y = Matrix::gaussian(a, b, 0.02, &mut rng);

        bench(
            &format!("adapter_forward m={m} n={n} a={a} b={b} rows={rows}"),
            400,
            || {
                black_box(adapter_forward(&x, &l, &r, &y, 2.0));
            },
        );
        if m <= 512 {
            bench(
                &format!("materialize ΔW + matmul m={m} n={n}"),
                400,
                || {
                    let d = materialize_delta(&l, &y, &r, 2.0);
                    black_box(x.matmul(&d.transpose()));
                },
            );
        }
    }

    println!("\n== projection regeneration from seed (adapter load path) ==");
    for (m, a) in [(512, 128), (2048, 1024)] {
        bench(&format!("regen_l m={m} a={a}"), 300, || {
            black_box(regen_l(7, "bench.l", m, a));
        });
    }
}
