//! Bench: raw per-kernel GFLOP/s for every `linalg` backend — the
//! regression baseline behind `BENCH_baseline.json`.
//!
//! Unlike `adapter_fwd` (which times the chained adapter products), this
//! times each GEMM kernel (NN / NT / TN) in isolation, per backend, at
//! paper shapes, single-threaded (the acceptance metric: packed ≥ 1.5×
//! tiled on NN/NT/TN) and with auto threads.  A deep-k TN section
//! covers the packed A-operand path at the gradient shape, a
//! wide-short NT section covers the packed backend's per-block column
//! parallelism (rows too few to split — columns carry the threads),
//! and a sparse-left section covers the threaded nonzero-row-index
//! kernel.  Everything lands in the
//! `linalg_kernels` section of `BENCH_linalg.json`, which
//! `tools/bench_regression.py` compares against the committed
//! `BENCH_baseline.json`.

use cosa::linalg::{self, sparse, Backend, Kind, Packed, Reference, Tiled};
use cosa::math::matrix::Matrix;
use cosa::math::rng::Pcg64;
use cosa::util::bench::{bench, black_box, write_bench_json};
use cosa::util::json::{obj, Json};

struct Bk {
    name: &'static str,
    threads: usize,
    make: fn(usize) -> Box<dyn Backend>,
}

fn backends() -> Vec<Bk> {
    fn mk_ref(_t: usize) -> Box<dyn Backend> {
        Box::new(Reference)
    }
    fn mk_tiled(t: usize) -> Box<dyn Backend> {
        Box::new(Tiled::new(t))
    }
    fn mk_packed(t: usize) -> Box<dyn Backend> {
        Box::new(Packed::new(t))
    }
    vec![
        Bk { name: "reference", threads: 1, make: mk_ref },
        Bk { name: "tiled", threads: 1, make: mk_tiled },
        Bk { name: "packed", threads: 1, make: mk_packed },
        Bk { name: "tiled", threads: 0, make: mk_tiled },
        Bk { name: "packed", threads: 0, make: mk_packed },
    ]
}

#[allow(clippy::too_many_arguments)]
fn push_row(rows: &mut Vec<Json>, kernel: &str, backend: &str,
            threads: usize, m: usize, k: usize, n: usize, mean_ns: f64,
            min_ns: f64, gflops: f64) {
    rows.push(obj(vec![
        ("kernel", kernel.into()),
        ("backend", backend.into()),
        ("threads", threads.into()),
        ("m", m.into()),
        ("k", k.into()),
        ("n", n.into()),
        ("mean_ns", mean_ns.into()),
        ("min_ns", min_ns.into()),
        ("gflops", gflops.into()),
    ]));
}

fn main() {
    println!("== linalg_kernels: per-kernel GFLOP/s, simd level: {} ==",
             cosa::linalg::simd::level().name());
    let mut rows_json: Vec<Json> = Vec::new();
    let mut rng = Pcg64::new(5);

    // (m, k, n): a paper GLUE-ish square, the NLG L·Y panel, a big square
    let shapes = [(512usize, 512usize, 512usize), (2048, 1024, 256),
                  (1024, 1024, 1024)];
    for (m, k, n) in shapes {
        let a = Matrix::gaussian(m, k, 1.0, &mut rng);
        let b = Matrix::gaussian(k, n, 1.0, &mut rng);
        let bt = Matrix::gaussian(n, k, 1.0, &mut rng);
        let at = Matrix::gaussian(k, m, 1.0, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        for bk in backends() {
            // auto-thread rows only at the largest shape (the serial
            // rows are the acceptance metric; threaded rows show scaling)
            if bk.threads == 0 && (m, k, n) != (1024, 1024, 1024) {
                continue;
            }
            let be = (bk.make)(bk.threads);
            let mut out = Matrix::zeros(m, n);
            let r = bench(
                &format!("nn[{}/t{}] {m}x{k}x{n}", bk.name, bk.threads),
                300,
                || {
                    be.gemm_into(&a, &b, &mut out);
                    black_box(out.data[0]);
                },
            );
            r.report_gflops(flops);
            push_row(&mut rows_json, "nn", bk.name, bk.threads, m, k, n,
                     r.mean_ns, r.min_ns, r.gflops(flops));

            let mut out = Matrix::zeros(m, n);
            let r = bench(
                &format!("nt[{}/t{}] {m}x{k}x{n}", bk.name, bk.threads),
                300,
                || {
                    be.gemm_nt_into(&a, &bt, &mut out);
                    black_box(out.data[0]);
                },
            );
            r.report_gflops(flops);
            push_row(&mut rows_json, "nt", bk.name, bk.threads, m, k, n,
                     r.mean_ns, r.min_ns, r.gflops(flops));

            let mut out = Matrix::zeros(m, n);
            let r = bench(
                &format!("tn[{}/t{}] {m}x{k}x{n}", bk.name, bk.threads),
                300,
                || {
                    be.gemm_tn_into(&at, &b, &mut out);
                    black_box(out.data[0]);
                },
            );
            r.report_gflops(flops);
            push_row(&mut rows_json, "tn", bk.name, bk.threads, m, k, n,
                     r.mean_ns, r.min_ns, r.gflops(flops));
        }
    }

    // Deep-k TN: the gradient shape (k >> m, n) where the blocked
    // A-transpose pack pays for itself — the TN kernel streams the
    // packed A row-major instead of striding the k-major original.
    // These rows feed the relative packed-vs-tiled TN gate in
    // tools/bench_regression.py.
    println!("\n== deep-k tn (packed A operand) ==");
    let (m, k, n) = (256usize, 3072usize, 64usize);
    let at_deep = Matrix::gaussian(k, m, 1.0, &mut rng);
    let b_deep = Matrix::gaussian(k, n, 1.0, &mut rng);
    let flops = 2.0 * (m * k * n) as f64;
    for bk in backends() {
        // serial tiled/packed only: this section exists for the
        // single-threaded packed-vs-tiled ratio
        if bk.threads != 1 || bk.name == "reference" {
            continue;
        }
        let be = (bk.make)(bk.threads);
        let mut out = Matrix::zeros(m, n);
        let r = bench(
            &format!("tn[{}/t1] {m}x{k}x{n}", bk.name),
            300,
            || {
                be.gemm_tn_into(&at_deep, &b_deep, &mut out);
                black_box(out.data[0]);
            },
        );
        r.report_gflops(flops);
        push_row(&mut rows_json, "tn", bk.name, 1, m, k, n,
                 r.mean_ns, r.min_ns, r.gflops(flops));
    }

    // Wide-short NT: the serving decode shape (a handful of activation
    // rows against a wide weight panel, n >> m) where row-based
    // parallelism has nothing to split — the packed backend's
    // per-block column parallelism is what keeps every thread busy.
    // These rows feed the relative packed-vs-tiled wide-short gate in
    // tools/bench_regression.py (serial AND threaded: the threaded
    // ratio is the one the column split actually moves).
    println!("\n== wide-short nt (per-block column parallelism) ==");
    let (m, k, n) = (4usize, 512usize, 3072usize);
    let a_wide = Matrix::gaussian(m, k, 1.0, &mut rng);
    let bt_wide = Matrix::gaussian(n, k, 1.0, &mut rng);
    let flops = 2.0 * (m * k * n) as f64;
    for bk in backends() {
        // tiled/packed only, serial and auto-threaded
        if bk.name == "reference" {
            continue;
        }
        let be = (bk.make)(bk.threads);
        let mut out = Matrix::zeros(m, n);
        let r = bench(
            &format!("nt[{}/t{}] {m}x{k}x{n}", bk.name, bk.threads),
            300,
            || {
                be.gemm_nt_into(&a_wide, &bt_wide, &mut out);
                black_box(out.data[0]);
            },
        );
        r.report_gflops(flops);
        push_row(&mut rows_json, "nt", bk.name, bk.threads, m, k, n,
                 r.mean_ns, r.min_ns, r.gflops(flops));
    }

    // Sparse-left: a ~10%-dense core against a wide B; thread count is
    // taken from the process-wide setting, so pin it per pass.
    println!("\n== sparse-left (nonzero-row index) ==");
    let (m, k, c) = (1024usize, 1024usize, 512usize);
    let mut y = Matrix::zeros(m, k);
    for pos in rng.sample_indices(m * k, m * k / 10) {
        y.data[pos] = rng.normal() as f32;
    }
    let b = Matrix::gaussian(k, c, 1.0, &mut rng);
    let nnz = y.data.iter().filter(|v| **v != 0.0).count();
    let sflops = 2.0 * (nnz * c) as f64;
    if std::env::var("COSA_THREADS").is_ok() {
        // env wins over set_backend — the rows below would be mislabeled
        // and would poison a --update'd BENCH_baseline.json
        println!("warning: COSA_THREADS env override is active; skipping \
                  the sparse_left passes so row labels stay truthful");
    }
    for threads in [1usize, 0] {
        if std::env::var("COSA_THREADS").is_ok() {
            continue;
        }
        linalg::set_backend(Kind::Auto, threads);
        let mut out = Matrix::zeros(m, c);
        let r = bench(
            &format!("sparse_left[t{threads}] {m}x{k}x{c} nnz={nnz}"),
            300,
            || {
                sparse::gemm_sparse_left_into(&y, &b, &mut out);
                black_box(out.data[0]);
            },
        );
        r.report_gflops(sflops);
        push_row(&mut rows_json, "sparse_left", "sparse", threads, m, k,
                 c, r.mean_ns, r.min_ns, r.gflops(sflops));
    }
    linalg::set_backend(Kind::Auto, 0);

    write_bench_json("linalg_kernels", Json::Arr(rows_json));
}
