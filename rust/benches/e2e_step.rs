//! Bench: end-to-end train-step latency.
//!
//! Two tiers:
//!
//! 1. **Host-mirror CoSA step** (`train::HostCosaStep`: forward + analytic
//!    VJP + core update) — always runs, per `linalg` backend, with
//!    GFLOP/s and the workspace allocation counter (must stay flat after
//!    warmup).  This is the measurable form of the "workspace-reused
//!    step" contract.
//! 2. **XLA optimizer step** per (preset × method) — requires
//!    `make artifacts` and a real `xla` backend; skips cleanly otherwise.
//!
//! Emits an `e2e_step_host` section into `BENCH_linalg.json`.

use cosa::adapters::cosa::{adapter_forward, regen_l, regen_r};
use cosa::config::RunConfig;
use cosa::exp::harness::exp_train_cfg;
use cosa::linalg::{self, Kind};
use cosa::math::matrix::Matrix;
use cosa::math::rng::Pcg64;
use cosa::runtime::executor::Runtime;
use cosa::runtime::Registry;
use cosa::train::{HostCosaStep, Trainer};
use cosa::util::bench::{bench, black_box, write_bench_json};
use cosa::util::json::{obj, Json};

fn host_step_section() {
    println!("== e2e_step (host mirror): fwd + VJP + update, \
              workspace-reused ==");
    let mut rows_json: Vec<Json> = Vec::new();
    for (m, n, a, b, rows) in [
        (512, 512, 128, 64, 32),
        (2048, 2048, 64, 64, 32),
    ] {
        let mut rng = Pcg64::new(3);
        let x = Matrix::gaussian(rows, n, 1.0, &mut rng);
        let target = {
            let mut y_star = Matrix::zeros(a, b);
            for pos in rng.sample_indices(a * b, 16) {
                y_star.data[pos] = rng.normal() as f32;
            }
            adapter_forward(&x, &regen_l(9, "e2e.l", m, a),
                            &regen_r(9, "e2e.r", b, n), &y_star, 2.0)
        };
        // fwd: x·Rᵀ, u·Yᵀ, v·Lᵀ; residual; vjp: xRᵀ again, e·L, tᵀ·u; axpy
        let flops = 2.0 * rows as f64
            * (2 * (n * b) + b * a + a * m + m * a + a * b) as f64
            + (rows * m + a * b) as f64;

        for kind in [Kind::Reference, Kind::Tiled, Kind::Packed] {
            linalg::set_backend(kind, 0);
            if linalg::resolved_kind() != kind {
                println!("warning: COSA_BACKEND env override is active; \
                          skipping the {} pass so BENCH_linalg.json rows \
                          stay truthful", kind.name());
                continue;
            }
            let mut step = HostCosaStep::new(
                regen_l(9, "e2e.l", m, a),
                regen_r(9, "e2e.r", b, n),
                Matrix::zeros(a, b),
                2.0,
            );
            let lr = step.safe_lr(&x);
            step.step(&x, &target, lr); // warmup (workspace + buffers)
            let warm = step.fresh_allocs();
            let res = bench(
                &format!("host_cosa_step[{}] m={m} n={n} a={a} b={b} \
                          rows={rows}", kind.name()),
                800,
                || {
                    black_box(step.step(&x, &target, lr));
                },
            );
            res.report_gflops(flops);
            let leaked = step.fresh_allocs() - warm;
            println!("    matmul-output allocations after warmup: {leaked}");
            rows_json.push(obj(vec![
                ("bench", "host_cosa_step".into()),
                ("backend", kind.name().into()),
                ("m", m.into()),
                ("n", n.into()),
                ("a", a.into()),
                ("b", b.into()),
                ("rows", rows.into()),
                ("mean_ns", res.mean_ns.into()),
                ("gflops", res.gflops(flops).into()),
                ("allocs_after_warmup", leaked.into()),
            ]));
        }
    }
    linalg::set_backend(Kind::Auto, 0);
    write_bench_json("e2e_step_host", Json::Arr(rows_json));
}

fn xla_section() -> anyhow::Result<()> {
    let reg = match Registry::open_default() {
        Ok(r) => r,
        Err(e) => {
            println!("\nskipping XLA e2e_step bench: {e}");
            return Ok(());
        }
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\nskipping XLA e2e_step bench: {e}");
            return Ok(());
        }
    };
    println!("\n== e2e_step: optimizer-step latency (XLA CPU) ==");
    for artifact in ["tiny-lm_cosa", "small-lm_cosa", "small-lm_lora",
                     "small-lm_full"] {
        if !reg.has(&format!("{artifact}_train")) {
            continue;
        }
        let cfg = RunConfig {
            name: format!("bench-{artifact}"),
            artifact: artifact.into(),
            task: "math".into(),
            train: exp_train_cfg(1, 1e-3),
            ..RunConfig::default()
        };
        let mut t = match Trainer::new(&rt, &reg, cfg) {
            Ok(t) => t,
            Err(e) => {
                println!("skipping {artifact}: {e}");
                continue;
            }
        };
        // warm the executable once outside the timer
        t.run()?;
        let batch = {
            // deterministic bench batch
            use cosa::data::batcher::lm_batch;
            use cosa::train::TaskData;
            match &t.data {
                TaskData::Lm(d) => {
                    let exs: Vec<&_> = d.train[..t.train_exec.meta.model.batch
                        .min(d.train.len())].iter().collect();
                    lm_batch(&exs, t.train_exec.meta.model.batch,
                             t.train_exec.meta.model.max_seq)
                }
                _ => unreachable!(),
            }
        };
        let state = &mut t.state;
        let exec = &t.train_exec;
        exec.take_profile();
        let r = bench(&format!("{artifact} train_step"), 1500, || {
            black_box(exec.train_step(state, 1e-4, 0.01, 1.0, &batch)
                .unwrap());
        });
        let tokens = (exec.meta.model.batch * exec.meta.model.max_seq) as f64;
        r.throughput(tokens, "tokens");
        println!("    {}", exec.take_profile().report());

        let eval_exec = &t.eval_exec;
        bench(&format!("{artifact} eval_step"), 800, || {
            black_box(eval_exec.eval_step(state, &batch).unwrap());
        });
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    host_step_section();
    xla_section()
}
