//! Bench: end-to-end train/eval step latency per (preset × method) — the
//! paper-table workloads' compute budget, plus executor overhead
//! decomposition (batch literal marshalling vs XLA execute).
//!
//! Requires `make artifacts`.

use cosa::config::RunConfig;
use cosa::exp::harness::exp_train_cfg;
use cosa::runtime::executor::Runtime;
use cosa::runtime::Registry;
use cosa::train::Trainer;
use cosa::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let reg = match Registry::open_default() {
        Ok(r) => r,
        Err(e) => {
            println!("skipping e2e_step bench: {e}");
            return Ok(());
        }
    };
    println!("== e2e_step: optimizer-step latency (XLA CPU) ==");
    for artifact in ["tiny-lm_cosa", "small-lm_cosa", "small-lm_lora",
                     "small-lm_full"] {
        if !reg.has(&format!("{artifact}_train")) {
            continue;
        }
        let cfg = RunConfig {
            name: format!("bench-{artifact}"),
            artifact: artifact.into(),
            task: "math".into(),
            train: exp_train_cfg(1, 1e-3),
            ..RunConfig::default()
        };
        let mut t = Trainer::new(&rt, &reg, cfg)?;
        // warm the executable once outside the timer
        t.run()?;
        let batch = {
            // deterministic bench batch
            use cosa::data::batcher::lm_batch;
            use cosa::train::TaskData;
            match &t.data {
                TaskData::Lm(d) => {
                    let exs: Vec<&_> = d.train[..t.train_exec.meta.model.batch
                        .min(d.train.len())].iter().collect();
                    lm_batch(&exs, t.train_exec.meta.model.batch,
                             t.train_exec.meta.model.max_seq)
                }
                _ => unreachable!(),
            }
        };
        let state = &mut t.state;
        let exec = &t.train_exec;
        exec.take_profile();
        let r = bench(&format!("{artifact} train_step"), 1500, || {
            black_box(exec.train_step(state, 1e-4, 0.01, 1.0, &batch)
                .unwrap());
        });
        let tokens = (exec.meta.model.batch * exec.meta.model.max_seq) as f64;
        r.throughput(tokens, "tokens");
        println!("    {}", exec.take_profile().report());

        let eval_exec = &t.eval_exec;
        bench(&format!("{artifact} eval_step"), 800, || {
            black_box(eval_exec.eval_step(state, &batch).unwrap());
        });
    }
    Ok(())
}
