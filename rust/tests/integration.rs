//! Integration tests over real AOT artifacts: the L3 runtime executing
//! L2-lowered XLA programs containing the L1 Pallas kernel.
//!
//! All tests skip (with a message) when `artifacts/` has not been built.
//! PJRT client creation is serialized behind a mutex — one CPU client at
//! a time keeps the thread pools sane under the parallel test runner.

use std::sync::Mutex;

use cosa::config::{RunConfig, Schedule, TrainConfig};
use cosa::runtime::executor::Runtime;
use cosa::runtime::Registry;
use cosa::train::checkpoint::Checkpoint;
use cosa::train::Trainer;

static PJRT_LOCK: Mutex<()> = Mutex::new(());

/// Serialize PJRT usage; recover from poison so one failing test does
/// not cascade into every other test.
fn pjrt_guard() -> std::sync::MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn setup() -> Option<(Runtime, Registry)> {
    let reg = match Registry::open_default() {
        Ok(r) => r,
        Err(_) => {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
    };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    Some((rt, reg))
}

fn quick_cfg(artifact: &str, steps: usize, lr: f64) -> RunConfig {
    RunConfig {
        name: format!("it-{artifact}"),
        artifact: artifact.to_string(),
        task: "math".into(),
        train: TrainConfig {
            steps,
            lr,
            weight_decay: 0.01,
            clip_norm: 1.0,
            schedule: Schedule::Constant,
            eval_every: 0,
            log_every: 0,
            grad_accum: 1,
        },
        out_dir: std::env::temp_dir().join("cosa-it").to_str().unwrap()
            .to_string(),
        ..RunConfig::default()
    }
}

#[test]
fn train_decreases_loss_for_cosa_lora_full() {
    let _g = pjrt_guard();
    let Some((rt, reg)) = setup() else { return };
    for (artifact, lr) in [("tiny-lm_cosa", 3e-3), ("tiny-lm_lora", 3e-3),
                           ("tiny-lm_full", 3e-4)] {
        let mut t = Trainer::new(&rt, &reg, quick_cfg(artifact, 30, lr))
            .unwrap();
        t.run().unwrap();
        let first = t.log.first_loss();
        let last = t.log.recent_loss(5);
        assert!(last < first * 0.95, "{artifact}: {first} -> {last}");
    }
}

#[test]
fn zero_init_adapters_match_base_model() {
    // Paper §4.1: with Y=0 (resp. B=0) the adapted model IS the base
    // model, so pristine eval losses must agree across methods — and
    // PiSSA's residual+SVD split must reconstruct the same function.
    let _g = pjrt_guard();
    let Some((rt, reg)) = setup() else { return };
    let mut losses = Vec::new();
    for artifact in ["tiny-lm_cosa", "tiny-lm_lora", "tiny-lm_pissa"] {
        let t = Trainer::new(&rt, &reg, quick_cfg(artifact, 1, 1e-3))
            .unwrap();
        let (loss, _) = t.evaluate().unwrap();
        losses.push(loss);
    }
    assert!((losses[0] - losses[1]).abs() < 1e-4,
            "cosa vs lora pristine: {losses:?}");
    assert!((losses[0] - losses[2]).abs() < 2e-3,
            "pissa reconstruction: {losses:?}");
}

#[test]
fn training_is_deterministic_given_seeds() {
    let _g = pjrt_guard();
    let Some((rt, reg)) = setup() else { return };
    let losses: Vec<Vec<f64>> = (0..2)
        .map(|_| {
            let mut t = Trainer::new(&rt, &reg,
                                     quick_cfg("tiny-lm_cosa", 8, 2e-3))
                .unwrap();
            t.run().unwrap();
            t.log.rows.iter().map(|r| r.2).collect()
        })
        .collect();
    assert_eq!(losses[0], losses[1], "same seeds must reproduce exactly");
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let _g = pjrt_guard();
    let Some((rt, reg)) = setup() else { return };
    let mut t = Trainer::new(&rt, &reg, quick_cfg("tiny-lm_cosa", 12, 3e-3))
        .unwrap();
    t.run().unwrap();
    let (loss_trained, _) = t.evaluate().unwrap();
    let path = std::env::temp_dir().join("cosa-it/roundtrip.ckpt");
    t.save_checkpoint(&path).unwrap();

    let mut t2 = Trainer::new(&rt, &reg, quick_cfg("tiny-lm_cosa", 1, 3e-3))
        .unwrap();
    t2.load_checkpoint(&Checkpoint::load(&path).unwrap()).unwrap();
    let (loss_reloaded, _) = t2.evaluate().unwrap();
    assert!((loss_trained - loss_reloaded).abs() < 1e-6,
            "{loss_trained} vs {loss_reloaded}");
}

#[test]
fn cls_head_trains_on_nlu_task() {
    let _g = pjrt_guard();
    let Some((rt, reg)) = setup() else { return };
    let mut cfg = quick_cfg("tiny-cls_cosa", 80, 5e-3);
    cfg.task = "nlu:sst2-sim".into();
    let mut t = Trainer::new(&rt, &reg, cfg).unwrap();
    let (_, acc0) = t.evaluate().unwrap();
    t.run().unwrap();
    let (_, acc) = t.evaluate().unwrap();
    assert!(acc > 0.55, "sst2-sim accuracy {acc} is not above chance");
    assert!(acc > acc0 - 0.05, "accuracy regressed: {acc0} -> {acc}");
}

#[test]
fn greedy_decode_produces_terminated_sequences() {
    let _g = pjrt_guard();
    let Some((rt, reg)) = setup() else { return };
    let mut t = Trainer::new(&rt, &reg, quick_cfg("tiny-lm_cosa", 60, 3e-3))
        .unwrap();
    t.run().unwrap();
    let cosa::train::TaskData::Lm(d) = &t.data else { panic!() };
    let exs: Vec<&_> = d.eval[..8].iter().collect();
    let gen = cosa::eval::greedy_decode(&t.eval_exec, &t.state, &exs, 12)
        .unwrap();
    assert_eq!(gen.len(), 8);
    let vocab = t.eval_exec.meta.model.vocab;
    for g in &gen {
        // decode mechanics: non-empty, bounded, EOS only at the end
        assert!(!g.is_empty() && g.len() <= 12, "{g:?}");
        if let Some(pos) =
            g.iter().position(|tok| *tok == cosa::data::tokenizer::EOS)
        {
            assert_eq!(pos, g.len() - 1, "EOS mid-sequence: {g:?}");
        }
        assert!(g.iter().all(|tok| (*tok as usize) < vocab));
    }
}

#[test]
fn missing_artifact_errors_cleanly() {
    let _g = pjrt_guard();
    let Some((rt, reg)) = setup() else { return };
    let err = Trainer::new(&rt, &reg, quick_cfg("tiny-lm_qlora", 1, 1e-3));
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn vera_and_dora_artifacts_execute() {
    let _g = pjrt_guard();
    let Some((rt, reg)) = setup() else { return };
    for artifact in ["small-lm_vera", "small-lm_dora", "small-lm_nola",
                     "small-lm_adalora"] {
        if !reg.has(&format!("{artifact}_train")) {
            continue;
        }
        let mut t = Trainer::new(&rt, &reg, quick_cfg(artifact, 4, 1e-3))
            .unwrap();
        t.run().unwrap();
        assert!(t.log.rows.iter().all(|r| r.2.is_finite()),
                "{artifact} produced non-finite loss");
    }
}
