// lint: hot-path
//! Fixture: a hot-path file where every allocation lives in a setup
//! fn (recognized by name or by a `// lint: setup` mark).

pub struct Scratch {
    buf: Vec<f32>,
}

impl Scratch {
    pub fn new(n: usize) -> Scratch {
        Scratch { buf: vec![0.0; n] }
    }

    pub fn with_capacity(n: usize) -> Scratch {
        let mut buf = Vec::new();
        buf.reserve(n);
        Scratch { buf }
    }

    pub fn step(&mut self) -> f32 {
        for v in self.buf.iter_mut() {
            *v *= 2.0;
        }
        self.buf.iter().sum()
    }
}

// lint: setup
fn warm() -> Vec<f32> {
    vec![1.0; 8]
}
