//! Fixture: lock acquisitions that respect the declared hierarchy —
//! outermost-first nesting, drop-before-reacquire, and statement
//! temporaries that die at the `;`.

use std::sync::{Mutex, RwLock};

pub struct State {
    pub server: RwLock<u32>,
    pub queue: Mutex<Vec<u32>>,
    pub model: Mutex<u32>,
    pub bufs: Mutex<Vec<f32>>,
}

pub fn outermost_first(s: &State) -> u32 {
    let srv = s.server.read().unwrap_or_else(|p| p.into_inner());
    let q = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    let m = s.model.lock().unwrap_or_else(|p| p.into_inner());
    *srv + q.len() as u32 + *m
}

pub fn drop_before_reacquire(s: &State) -> u32 {
    let m = s.model.lock().unwrap_or_else(|p| p.into_inner());
    let v = *m;
    drop(m);
    let q = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    v + q.len() as u32
}

pub fn temporary_guard_then_outer(s: &State) -> u32 {
    let len = s.bufs.lock().unwrap_or_else(|p| p.into_inner()).len();
    let q = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    len as u32 + q.len() as u32
}
