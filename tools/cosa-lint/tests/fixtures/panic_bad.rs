//! Fixture: five distinct panic sites — all must be reported when the
//! file sits in a request-path module, none when it does not.

pub fn f1(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn f2(v: Option<u32>) -> u32 {
    v.expect("present")
}

pub fn f3(x: u32) -> u32 {
    if x > 10 {
        panic!("too big");
    }
    x
}

pub fn f4(x: u32) -> u32 {
    match x {
        0 => 1,
        _ => unreachable!(),
    }
}

pub fn f5() -> u32 {
    todo!()
}
