//! Fixture: condvar waits whose only live guard is the one handed to
//! the condvar (the lock the wait actually releases), plus the two
//! deliberate scope edges — drop-before-wait for an unrelated guard,
//! and an arg-less `.wait()` that is not a condvar call at all.

use std::sync::{Condvar, Mutex};

pub struct State {
    pub queue: Mutex<(Vec<u32>, bool)>,
    pub model: Mutex<u32>,
    pub cv: Condvar,
}

pub struct Ticket;

impl Ticket {
    pub fn wait(&self) -> u32 {
        7
    }
}

pub fn wait_sole_guard(s: &State) -> u32 {
    let mut g = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    loop {
        if let Some(v) = g.0.pop() {
            return v;
        }
        if g.1 {
            return 0;
        }
        g = s.cv.wait(g).unwrap_or_else(|p| p.into_inner());
    }
}

pub fn drop_other_guard_before_wait(s: &State) -> u32 {
    let m = s.model.lock().unwrap_or_else(|p| p.into_inner());
    let seed = *m;
    drop(m);
    let g = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    let g = s
        .cv
        .wait_while(g, |q| q.0.is_empty())
        .unwrap_or_else(|p| p.into_inner());
    seed + g.0.len() as u32
}

pub fn argless_wait_is_not_a_condvar(s: &State, t: &Ticket) -> u32 {
    // `Ticket::wait()` takes no guard — nothing for a condvar to
    // release, so the condvar rule does not apply.
    let m = s.model.lock().unwrap_or_else(|p| p.into_inner());
    *m + t.wait()
}
