//! Fixture: same-level locks nested in ONE consistent order across
//! fns — legal under the hierarchy (levels only order across levels)
//! and legal under the nesting reconciliation (no opposite order
//! anywhere in the file).

use std::sync::Mutex;

pub struct State {
    pub q: Mutex<Vec<u32>>,
    pub queue: Mutex<Vec<u32>>,
}

pub fn drain_fast(s: &State) -> u32 {
    let a = s.q.lock().unwrap_or_else(|p| p.into_inner());
    let b = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    a.len() as u32 + b.len() as u32
}

pub fn drain_slow(s: &State) -> u32 {
    let a = s.q.lock().unwrap_or_else(|p| p.into_inner());
    let b = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    (a.len() + b.len()) as u32
}

pub fn disjoint(s: &State) -> u32 {
    let a = s.q.lock().unwrap_or_else(|p| p.into_inner());
    let n = a.len() as u32;
    drop(a);
    let b = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    n + b.len() as u32
}
