//! Fixture: one hierarchy inversion plus two hygiene violations
//! (filesystem I/O and a `read_*` call while a guard is live).

use std::sync::Mutex;

pub struct State {
    pub queue: Mutex<Vec<u32>>,
    pub model: Mutex<u32>,
}

fn read_checkpoint(path: &str) -> u32 {
    path.len() as u32
}

pub fn inverted(s: &State) -> u32 {
    let m = s.model.lock().unwrap_or_else(|p| p.into_inner());
    let q = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    *m + q.len() as u32
}

pub fn io_under_lock(s: &State) -> u32 {
    let m = s.model.lock().unwrap_or_else(|p| p.into_inner());
    let side = read_checkpoint("ckpt.bin");
    let f = std::fs::File::open("ckpt.bin");
    drop(f);
    *m + side
}
