//! Fixture: two `unsafe` sites with no justification — both must be
//! reported by unsafe-audit.

pub fn naked(data: &[f32]) -> &[u8] {
    let n = data.len() * 4;
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, n) }
}

pub unsafe fn kernel(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v += 1.0;
    }
}
