//! Fixture: a request-path module with no panic findings — errors
//! propagate, one panic is reason-allowed, and test code is exempt.

pub fn get(v: &[u32], i: usize) -> Result<u32, String> {
    v.get(i).copied().ok_or_else(|| format!("index {i} out of range"))
}

pub fn fallback(v: Option<u32>) -> u32 {
    v.unwrap_or_default()
}

pub fn justified(v: Option<u32>) -> u32 {
    // lint: allow(panic) — fixture demonstrating a reasoned allow.
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
