//! Fixture: every `unsafe` carries a `// SAFETY:` justification in
//! one of the three accepted placements.

/// Comment on the lines directly above the statement.
pub fn bytes(data: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding, u8 has alignment 1, and the length
    // covers exactly the borrowed buffer.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    }
}

/// Comment as the first token inside the unsafe block.
pub fn inner_comment(data: &[f32]) -> &[u8] {
    unsafe {
        // SAFETY: same invariants as `bytes` above.
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    }
}

// SAFETY: callers must verify the `avx2` feature at runtime before
// dispatching here — the comment may sit above the attribute stack.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn kernel(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v += 1.0;
    }
}
