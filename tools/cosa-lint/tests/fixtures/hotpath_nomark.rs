//! Fixture: the same allocation patterns, but the file never opts in
//! with `// lint: hot-path`, so the alloc rule stays silent.

pub fn step(xs: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    out.extend(xs.to_vec());
    out
}
