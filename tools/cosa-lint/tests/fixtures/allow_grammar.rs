//! Fixture: the `lint:` directive grammar.  A reasoned allow
//! suppresses its finding; a reason-less allow and an unknown rule
//! each produce an `allowlist` finding AND leave the original
//! finding in place.

pub fn good(v: Option<u32>) -> u32 {
    // lint: allow(panic) — invariant: caller checked is_some().
    v.unwrap()
}

pub fn missing_reason(v: Option<u32>) -> u32 {
    // lint: allow(panic)
    v.unwrap()
}

pub fn unknown_rule(v: Option<u32>) -> u32 {
    // lint: allow(crashes) — not a rule family.
    v.unwrap()
}
