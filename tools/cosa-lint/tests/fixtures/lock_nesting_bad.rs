//! Fixture: two same-level (`scheduler`) locks nested in OPPOSITE
//! orders across two fns — each fn passes the rank hierarchy on its
//! own, but together they form an ABBA deadlock the reconciliation
//! pass must flag exactly once.

use std::sync::Mutex;

pub struct State {
    pub q: Mutex<Vec<u32>>,
    pub queue: Mutex<Vec<u32>>,
}

pub fn fn_a(s: &State) -> u32 {
    let a = s.q.lock().unwrap_or_else(|p| p.into_inner());
    let b = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    a.len() as u32 + b.len() as u32
}

pub fn fn_b(s: &State) -> u32 {
    let b = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    let a = s.q.lock().unwrap_or_else(|p| p.into_inner());
    b.len() as u32 + a.len() as u32
}
