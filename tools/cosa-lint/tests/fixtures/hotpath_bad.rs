// lint: hot-path
//! Fixture: five allocation sites on the hot path — Vec::new, vec!,
//! .to_vec(), Box::new, and a turbofish .collect::<..>().

pub fn step(xs: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    out.extend(xs.iter().map(|v| v * 2.0));
    let tail = vec![0.0f32; 2];
    let copied = xs.to_vec();
    let boxed = Box::new(1.0f32);
    let squares = xs.iter().map(|v| v * v).collect::<Vec<f32>>();
    out.extend(tail);
    out.extend(copied);
    out.push(*boxed);
    out.extend(squares);
    out
}
