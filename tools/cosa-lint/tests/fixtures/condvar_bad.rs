//! Fixture: condvar waits that park the thread while a *different*
//! guard stays held.  The condvar releases only the guard it is
//! passed; every other live lock blocks its contenders for the whole
//! sleep.  One violation per wait form, each with correctly-ordered
//! acquisitions so only the condvar rule fires.

use std::sync::{Condvar, Mutex};

pub struct State {
    pub queue: Mutex<(Vec<u32>, bool)>,
    pub ingress: Mutex<Vec<u32>>,
    pub model: Mutex<u32>,
    pub bufs: Mutex<Vec<f32>>,
    pub cv: Condvar,
}

pub fn wait_holding_model(s: &State) -> u32 {
    let g = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    let m = s.model.lock().unwrap_or_else(|p| p.into_inner());
    let g = s.cv.wait(g).unwrap_or_else(|p| p.into_inner());
    g.0.len() as u32 + *m
}

pub fn timeout_holding_pool(s: &State) -> u32 {
    let g = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    let b = s.bufs.lock().unwrap_or_else(|p| p.into_inner());
    let (g, _timed_out) = s
        .cv
        .wait_timeout(g, std::time::Duration::from_millis(5))
        .unwrap_or_else(|p| p.into_inner());
    g.0.len() as u32 + b.len() as u32
}

pub fn wait_while_holding_peer(s: &State) -> u32 {
    let a = s.ingress.lock().unwrap_or_else(|p| p.into_inner());
    let g = s.queue.lock().unwrap_or_else(|p| p.into_inner());
    let g = s
        .cv
        .wait_while(g, |q| q.0.is_empty())
        .unwrap_or_else(|p| p.into_inner());
    a.len() as u32 + g.0.len() as u32
}
