//! Golden-fixture suite: one passing and one failing fixture per rule
//! family, checked through the library with *virtual paths* (so the
//! path-scoped rules behave as if the fixture lived in a request-path
//! module, regardless of where `tests/fixtures/` actually sits), plus
//! end-to-end exit-code tests against the compiled binary.

use std::path::{Path, PathBuf};
use std::process::Command;

use cosa_lint::{check_source, Config, Finding};

fn manifest_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> String {
    let p = manifest_dir().join("tests/fixtures").join(name);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

fn repo_config() -> Config {
    Config::load(&manifest_dir().join("lock_order.toml")).unwrap()
}

fn check(name: &str, vpath: &str) -> Vec<Finding> {
    check_source(vpath, &fixture(name), &repo_config())
}

fn count_rule(fs: &[Finding], rule: &str) -> usize {
    fs.iter().filter(|f| f.rule == rule).count()
}

// ------------------------------------------------------ unsafe-audit

#[test]
fn unsafe_ok_is_clean() {
    let fs = check("unsafe_ok.rs", "rust/src/linalg/unsafe_ok.rs");
    assert!(fs.is_empty(), "unexpected findings: {fs:?}");
}

#[test]
fn unsafe_bad_reports_both_sites() {
    let fs = check("unsafe_bad.rs", "rust/src/linalg/unsafe_bad.rs");
    assert_eq!(count_rule(&fs, "unsafe-audit"), 2, "findings: {fs:?}");
    assert_eq!(fs.len(), 2);
    let lines: Vec<u32> = fs.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![6, 9]);
}

// ----------------------------------------------------- panic-freedom

#[test]
fn panic_ok_is_clean() {
    let fs = check("panic_ok.rs", "rust/src/serve/panic_ok.rs");
    assert!(fs.is_empty(), "unexpected findings: {fs:?}");
}

#[test]
fn panic_bad_reports_all_five_forms() {
    let fs = check("panic_bad.rs", "rust/src/serve/panic_bad.rs");
    assert_eq!(count_rule(&fs, "panic-freedom"), 5, "findings: {fs:?}");
    assert_eq!(fs.len(), 5);
}

#[test]
fn panic_rule_only_applies_to_request_path_modules() {
    // The exact same source outside serve/wire/model/linalg/obs is
    // fine.
    let fs = check("panic_bad.rs", "rust/src/exp/panic_bad.rs");
    assert!(fs.is_empty(), "unexpected findings: {fs:?}");
}

#[test]
fn panic_rule_covers_the_obs_module() {
    // The telemetry layer sits on the request path (Trace is stamped
    // inside scheduler workers) — a panic there kills serving threads
    // just like one in serve/, so obs/ is held to the same rule.
    let fs = check("panic_bad.rs", "rust/src/obs/panic_bad.rs");
    assert_eq!(count_rule(&fs, "panic-freedom"), 5, "findings: {fs:?}");
}

// -------------------------------------------- lock-order + hygiene

#[test]
fn lock_ok_is_clean() {
    let fs = check("lock_ok.rs", "rust/src/serve/lock_ok.rs");
    assert!(fs.is_empty(), "unexpected findings: {fs:?}");
}

#[test]
fn lock_bad_reports_inversion_and_hygiene() {
    let fs = check("lock_bad.rs", "rust/src/serve/lock_bad.rs");
    assert_eq!(count_rule(&fs, "lock-order"), 1, "findings: {fs:?}");
    assert_eq!(count_rule(&fs, "lock-hygiene"), 2, "findings: {fs:?}");
    assert_eq!(fs.len(), 3);
    let inv = fs.iter().find(|f| f.rule == "lock-order").unwrap();
    assert!(
        inv.msg.contains("`scheduler`") && inv.msg.contains("`model`"),
        "msg: {}",
        inv.msg
    );
}

// -------------------------------------------------- lock-nesting

#[test]
fn lock_nesting_one_consistent_order_is_clean() {
    let fs = check(
        "lock_nesting_ok.rs",
        "rust/src/serve/lock_nesting_ok.rs",
    );
    assert!(fs.is_empty(), "unexpected findings: {fs:?}");
}

#[test]
fn lock_nesting_opposite_orders_flag_once_per_pair() {
    let fs = check(
        "lock_nesting_bad.rs",
        "rust/src/serve/lock_nesting_bad.rs",
    );
    assert_eq!(count_rule(&fs, "lock-nesting"), 1, "findings: {fs:?}");
    assert_eq!(
        fs.len(),
        1,
        "each fn passes the rank hierarchy on its own: {fs:?}"
    );
    let f = &fs[0];
    assert_eq!(f.line, 15, "anchor on the first direction seen");
    assert!(
        f.msg.contains("`s.q`")
            && f.msg.contains("`s.queue`")
            && f.msg.contains("opposite"),
        "msg: {}",
        f.msg
    );
}

// --------------------------------------------------- condvar-wait

#[test]
fn condvar_ok_is_clean() {
    let fs = check("condvar_ok.rs", "rust/src/serve/condvar_ok.rs");
    assert!(fs.is_empty(), "unexpected findings: {fs:?}");
}

#[test]
fn condvar_bad_reports_each_wait_form_once() {
    let fs = check("condvar_bad.rs", "rust/src/serve/condvar_bad.rs");
    assert_eq!(count_rule(&fs, "condvar-wait"), 3, "findings: {fs:?}");
    assert_eq!(fs.len(), 3, "only the condvar rule may fire: {fs:?}");
    for (finding, lock) in fs.iter().zip(["model", "outpool",
                                          "scheduler"]) {
        assert!(
            finding.msg.contains(&format!("`{lock}`")),
            "expected the held `{lock}` lock in: {}",
            finding.msg
        );
    }
}

// --------------------------------------------------- hot-path-alloc

#[test]
fn hotpath_ok_is_clean() {
    let fs = check("hotpath_ok.rs", "rust/src/linalg/hotpath_ok.rs");
    assert!(fs.is_empty(), "unexpected findings: {fs:?}");
}

#[test]
fn hotpath_bad_reports_all_five_alloc_forms() {
    let fs = check("hotpath_bad.rs", "rust/src/linalg/hotpath_bad.rs");
    assert_eq!(count_rule(&fs, "hot-path-alloc"), 5, "findings: {fs:?}");
    assert_eq!(fs.len(), 5);
}

#[test]
fn alloc_rule_is_opt_in_per_file() {
    let fs =
        check("hotpath_nomark.rs", "rust/src/linalg/hotpath_nomark.rs");
    assert!(fs.is_empty(), "unexpected findings: {fs:?}");
}

// -------------------------------------------------- allow grammar

#[test]
fn allow_grammar_requires_reasons_and_known_rules() {
    let fs = check("allow_grammar.rs", "rust/src/serve/allow_grammar.rs");
    // Reason-less allow and unknown-rule allow each yield an
    // `allowlist` finding AND fail to suppress the panic finding;
    // the reasoned allow in `good()` suppresses its unwrap.
    assert_eq!(count_rule(&fs, "allowlist"), 2, "findings: {fs:?}");
    assert_eq!(count_rule(&fs, "panic-freedom"), 2, "findings: {fs:?}");
    assert_eq!(fs.len(), 4);
    assert!(
        fs.iter().any(|f| f.msg.contains("without a reason")),
        "findings: {fs:?}"
    );
    assert!(
        fs.iter().any(|f| f.msg.contains("unknown rule `crashes`")),
        "findings: {fs:?}"
    );
}

// ------------------------------------------------ config tamper gate

#[test]
fn removing_a_rule_family_is_a_config_error() {
    let toml = std::fs::read_to_string(
        manifest_dir().join("lock_order.toml"),
    )
    .unwrap();
    for fam in cosa_lint::REQUIRED_FAMILIES {
        let cut = toml.replace(&format!("\"{fam}\","), "");
        let err = Config::parse(&cut)
            .expect_err("family removal must not parse");
        assert!(err.contains(fam), "err for {fam}: {err}");
    }
}

// --------------------------------------------- binary exit codes

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_cosa-lint")
}

/// A scratch tree under the workspace target dir (no temp-dir races,
/// cleaned by `cargo clean`, ignored by git).
fn scratch(tag: &str) -> PathBuf {
    let d = manifest_dir().join("../../target/lint-scratch").join(tag);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn write(p: &Path, content: &str) {
    std::fs::create_dir_all(p.parent().unwrap()).unwrap();
    std::fs::write(p, content).unwrap();
}

#[test]
fn binary_exits_one_and_prints_findings_on_a_dirty_tree() {
    let d = scratch("dirty");
    write(
        &d.join("src/linalg/bad.rs"),
        &fixture("unsafe_bad.rs"),
    );
    let out = Command::new(bin())
        .args(["--check"])
        .arg(&d)
        .args(["--config"])
        .arg(manifest_dir().join("lock_order.toml"))
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1), "out: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("[unsafe-audit]"), "stdout: {stdout}");
    assert!(stdout.contains("bad.rs:6"), "stdout: {stdout}");
}

#[test]
fn binary_exits_zero_on_a_clean_tree() {
    let d = scratch("clean");
    write(&d.join("src/serve/ok.rs"), &fixture("panic_ok.rs"));
    let out = Command::new(bin())
        .args(["--check"])
        .arg(&d)
        .args(["--config"])
        .arg(manifest_dir().join("lock_order.toml"))
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(0), "out: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("clean"), "stdout: {stdout}");
}

#[test]
fn binary_exits_two_on_a_tampered_config() {
    let d = scratch("tampered");
    write(&d.join("src/serve/ok.rs"), &fixture("panic_ok.rs"));
    let toml = std::fs::read_to_string(
        manifest_dir().join("lock_order.toml"),
    )
    .unwrap();
    let cfg = d.join("lock_order.toml");
    write(&cfg, &toml.replace("\"lock-order\",", ""));
    let out = Command::new(bin())
        .args(["--check"])
        .arg(&d)
        .args(["--config"])
        .arg(&cfg)
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "out: {out:?}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("lock-order"), "stderr: {stderr}");
}

// ------------------------------------------------- repo self-check

#[test]
fn the_repo_itself_is_lint_clean() {
    // The CI gate in miniature: the committed tree must stay clean
    // (every remaining panic/unsafe carries a reasoned annotation).
    let repo = manifest_dir().join("../..");
    let out = Command::new(bin())
        .args(["--check"])
        .arg(&repo)
        .args(["--config"])
        .arg(manifest_dir().join("lock_order.toml"))
        .output()
        .unwrap();
    assert_eq!(
        out.status.code(),
        Some(0),
        "stdout:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
}
