//! CI entry point: `cargo run -p cosa-lint -- --check rust`.
//!
//! Exit codes: 0 clean, 1 findings (printed `file:line: [rule] msg`),
//! 2 usage or configuration error.  A config that drops a required
//! rule family is a *config* error (exit 2), so CI fails loudly if
//! someone switches a family off instead of fixing its findings.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use cosa_lint::{run_check, Config};

const USAGE: &str = "usage: cosa-lint --check <dir> [--config <lock_order.toml>]

  --check <dir>    repo root, the rust crate dir, or any directory of
                   .rs files to lint
  --config <path>  lock hierarchy + enabled rule families
                   (default: tools/cosa-lint/lock_order.toml, searched
                   upward from the checked directory)";

/// Find `tools/cosa-lint/lock_order.toml` next to the checked tree:
/// try the CWD first, then every ancestor of the `--check` path.
fn default_config(check: &Path) -> Option<PathBuf> {
    let rel = Path::new("tools/cosa-lint/lock_order.toml");
    if rel.is_file() {
        return Some(rel.to_path_buf());
    }
    let abs = check.canonicalize().unwrap_or_else(|_| check.to_path_buf());
    let mut cur: Option<&Path> = Some(&abs);
    while let Some(dir) = cur {
        let cand = dir.join(rel);
        if cand.is_file() {
            return Some(cand);
        }
        cur = dir.parent();
    }
    None
}

fn main() -> ExitCode {
    let mut check: Option<PathBuf> = None;
    let mut config: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => match args.next() {
                Some(v) => check = Some(PathBuf::from(v)),
                None => {
                    eprintln!("cosa-lint: --check needs a directory");
                    return ExitCode::from(2);
                }
            },
            "--config" => match args.next() {
                Some(v) => config = Some(PathBuf::from(v)),
                None => {
                    eprintln!("cosa-lint: --config needs a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("cosa-lint: unknown argument `{other}`");
                eprintln!("{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    let Some(check) = check else {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    };
    let config = match config.or_else(|| default_config(&check)) {
        Some(c) => c,
        None => {
            eprintln!(
                "cosa-lint: no lock_order.toml found (pass --config)"
            );
            return ExitCode::from(2);
        }
    };
    let cfg = match Config::load(&config) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cosa-lint: config error: {e}");
            return ExitCode::from(2);
        }
    };
    match run_check(&check, &cfg) {
        Ok((findings, nfiles)) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                println!(
                    "cosa-lint: clean — {nfiles} file(s), 0 findings \
                     ({} families)",
                    cfg.families.len()
                );
                ExitCode::SUCCESS
            } else {
                println!(
                    "cosa-lint: {} finding(s) in {nfiles} file(s)",
                    findings.len()
                );
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("cosa-lint: {e}");
            ExitCode::from(2)
        }
    }
}
