//! cosa-lint — repo-invariant static analysis for the CoSA serving
//! stack, kept deliberately lexical and zero-dependency so the gate
//! itself can never rot behind a dependency bump or a compiler
//! upgrade.  Six rule families (see `rules`): unsafe-audit,
//! panic-freedom, lock-order (+ lock-hygiene), lock-nesting
//! (same-level ABBA reconciliation), hot-path-alloc, condvar-wait.
//!
//! The library surface exists so the golden-fixture tests can drive
//! `check_source` with virtual paths; the binary in `main.rs` is the
//! CI entry point.

pub mod config;
pub mod lexer;
pub mod rules;

pub use config::{Config, REQUIRED_FAMILIES};
pub use rules::{check_source, Finding};

use std::path::{Path, PathBuf};

/// Expand the `--check` argument into the directories to walk.
/// Accepts either the repo root (walks `rust/src`, `rust/benches`,
/// `examples`), the `rust` crate dir (walks its `src`/`benches` plus
/// a sibling `examples`), or any plain directory (walked as-is).
pub fn resolve_roots(arg: &Path) -> Vec<PathBuf> {
    let mut roots = Vec::new();
    if arg.join("rust/src").is_dir() {
        roots.push(arg.join("rust/src"));
        roots.push(arg.join("rust/benches"));
        roots.push(arg.join("examples"));
    } else if arg.join("src").is_dir() {
        roots.push(arg.join("src"));
        roots.push(arg.join("benches"));
        if let Some(parent) = arg.parent() {
            roots.push(parent.join("examples"));
        }
    } else {
        roots.push(arg.to_path_buf());
    }
    roots.retain(|r| r.is_dir());
    roots
}

/// All `.rs` files under `root`, recursively, in sorted order so the
/// report is deterministic.
pub fn collect_rs_files(root: &Path) -> Vec<PathBuf> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        let Ok(rd) = std::fs::read_dir(dir) else { return };
        let mut entries: Vec<PathBuf> =
            rd.flatten().map(|e| e.path()).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    let mut out = Vec::new();
    walk(root, &mut out);
    out
}

/// Lint every `.rs` file reachable from `check_arg`.  Returns the
/// findings (sorted by file then line) and the number of files
/// inspected.
pub fn run_check(
    check_arg: &Path,
    cfg: &Config,
) -> Result<(Vec<Finding>, usize), String> {
    let roots = resolve_roots(check_arg);
    if roots.is_empty() {
        return Err(format!(
            "--check {}: no lintable directories found",
            check_arg.display()
        ));
    }
    let mut files = Vec::new();
    for r in &roots {
        files.extend(collect_rs_files(r));
    }
    files.sort();
    files.dedup();
    let mut findings = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)
            .map_err(|e| format!("cannot read {}: {e}", f.display()))?;
        findings.extend(check_source(&f.display().to_string(), &src, cfg));
    }
    findings.sort_by(|a, b| {
        a.file.cmp(&b.file).then(a.line.cmp(&b.line))
    });
    Ok((findings, files.len()))
}
