//! A hand-rolled Rust lexer — deliberately *not* a full grammar, just
//! enough token fidelity for lexical lint rules to be exact where it
//! matters: comments (line + nested block), strings (plain, raw with
//! `#` fences, byte), char literals disambiguated from lifetimes,
//! identifiers, numbers, and single-character punctuation.  Multi-char
//! operators arrive as adjacent punct tokens (`::` is `:` `:`), which
//! the rules handle explicitly.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Str,
    Char,
    Lifetime,
    Num,
    Comment,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
    /// Last line the token touches (differs from `line` only for
    /// multi-line block comments and strings).
    pub end_line: u32,
}

impl Tok {
    pub fn is_punct(&self, ch: char) -> bool {
        self.kind == Kind::Punct && self.text.chars().next() == Some(ch)
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }
}

fn push(toks: &mut Vec<Tok>, kind: Kind, text: &[char], line: u32, end: u32) {
    toks.push(Tok { kind, text: text.iter().collect(), line, end_line: end });
}

/// Scan a raw/byte string starting at a `r`/`b` prefix.  Returns the
/// index just past the closing quote and the end line, or None if the
/// characters at `i` are not actually a string prefix (e.g. the ident
/// `break` starts with `b`, `r` may be a plain variable).
fn str_prefix(cs: &[char], i: usize, line: u32) -> Option<(usize, u32)> {
    let n = cs.len();
    let mut j = i;
    let mut pre = String::new();
    while j < n
        && (cs[j] == 'r' || cs[j] == 'b')
        && pre.len() < 2
        && !pre.contains(cs[j])
    {
        pre.push(cs[j]);
        j += 1;
    }
    let mut hashes = 0usize;
    if pre.contains('r') {
        while j < n && cs[j] == '#' {
            hashes += 1;
            j += 1;
        }
    }
    if j >= n || cs[j] != '"' {
        return None;
    }
    j += 1;
    let mut nl = line;
    if pre.contains('r') {
        // Raw string: no escapes; ends at `"` followed by `hashes` #s.
        while j < n {
            if cs[j] == '\n' {
                nl += 1;
                j += 1;
                continue;
            }
            if cs[j] == '"' {
                let mut h = 0usize;
                while h < hashes && j + 1 + h < n && cs[j + 1 + h] == '#' {
                    h += 1;
                }
                if h == hashes {
                    return Some((j + 1 + hashes, nl));
                }
            }
            j += 1;
        }
        return Some((j, nl));
    }
    // Byte string: ordinary escape rules.
    while j < n {
        match cs[j] {
            '\\' => {
                // A `\` at end-of-line is a line continuation — the
                // escaped newline still advances the line counter.
                if j + 1 < n && cs[j + 1] == '\n' {
                    nl += 1;
                }
                j += 2;
            }
            '\n' => {
                nl += 1;
                j += 1;
            }
            '"' => return Some((j + 1, nl)),
            _ => j += 1,
        }
    }
    Some((j, nl))
}

fn scan_dq(cs: &[char], i: usize, line: u32) -> (usize, u32) {
    let n = cs.len();
    let mut j = i + 1;
    let mut nl = line;
    while j < n {
        match cs[j] {
            '\\' => {
                if j + 1 < n && cs[j + 1] == '\n' {
                    nl += 1;
                }
                j += 2;
            }
            '\n' => {
                nl += 1;
                j += 1;
            }
            '"' => return (j + 1, nl),
            _ => j += 1,
        }
    }
    (j, nl)
}

pub fn lex(src: &str) -> Vec<Tok> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc `///` and inner `//!`).
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            push(&mut toks, Kind::Comment, &cs[start..i], line, line);
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let sl = line;
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            push(&mut toks, Kind::Comment, &cs[start..i], sl, line);
            continue;
        }
        // Raw / byte strings (r"..", r#".."#, b"..", br".."), before
        // the generic ident scan so the prefix letters don't lex as an
        // ident.
        if c == 'r' || c == 'b' {
            if let Some((j, nl)) = str_prefix(&cs, i, line) {
                push(&mut toks, Kind::Str, &cs[i..j], line, nl);
                i = j;
                line = nl;
                continue;
            }
        }
        if c == '"' {
            let (j, nl) = scan_dq(&cs, i, line);
            push(&mut toks, Kind::Str, &cs[i..j], line, nl);
            i = j;
            line = nl;
            continue;
        }
        // `'`: lifetime (`'a`) vs char literal (`'a'`, `'\n'`, `'{'`).
        if c == '\'' {
            let j = i + 1;
            if j < n && (cs[j] == '_' || cs[j].is_alphabetic()) {
                let mut k = j;
                while k < n && (cs[k] == '_' || cs[k].is_alphanumeric()) {
                    k += 1;
                }
                if k < n && cs[k] == '\'' {
                    push(&mut toks, Kind::Char, &cs[i..=k], line, line);
                    i = k + 1;
                } else {
                    push(&mut toks, Kind::Lifetime, &cs[i..k], line, line);
                    i = k;
                }
                continue;
            }
            if j < n && cs[j] == '\\' {
                let mut k = j + 1;
                if k < n && cs[k] == 'u' {
                    while k < n && cs[k] != '}' {
                        k += 1;
                    }
                    k += 1;
                } else {
                    k += 1;
                }
                while k < n && cs[k] != '\'' {
                    k += 1;
                }
                let end = (k + 1).min(n);
                push(&mut toks, Kind::Char, &cs[i..end], line, line);
                i = end;
                continue;
            }
            if j + 1 < n && cs[j + 1] == '\'' {
                push(&mut toks, Kind::Char, &cs[i..j + 2], line, line);
                i = j + 2;
                continue;
            }
            push(&mut toks, Kind::Punct, &cs[i..=i], line, line);
            i += 1;
            continue;
        }
        if c == '_' || c.is_alphabetic() {
            let start = i;
            while i < n && (cs[i] == '_' || cs[i].is_alphanumeric()) {
                i += 1;
            }
            push(&mut toks, Kind::Ident, &cs[start..i], line, line);
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (cs[i] == '_' || cs[i].is_alphanumeric()) {
                i += 1;
            }
            // A fractional part only when `.` is followed by a digit —
            // never swallow `..` ranges or `1.max(2)` method calls.
            if i < n
                && cs[i] == '.'
                && i + 1 < n
                && cs[i + 1].is_ascii_digit()
            {
                i += 1;
                while i < n && (cs[i] == '_' || cs[i].is_alphanumeric()) {
                    i += 1;
                }
            }
            push(&mut toks, Kind::Num, &cs[start..i], line, line);
            continue;
        }
        push(&mut toks, Kind::Punct, &cs[i..=i], line, line);
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn lifetimes_vs_chars() {
        let ks = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(ks.iter().any(|(k, t)| *k == Kind::Lifetime && t == "'a"));
        assert!(ks.iter().any(|(k, t)| *k == Kind::Char && t == "'x'"));
    }

    #[test]
    fn raw_strings_do_not_escape() {
        let ks = kinds(r##"let s = r#"a \" b"#; let t = 1;"##);
        assert!(ks.iter().any(|(k, _)| *k == Kind::Str));
        assert!(ks.iter().any(|(k, t)| *k == Kind::Ident && t == "t"));
    }

    #[test]
    fn nested_block_comments() {
        let ks = kinds("/* outer /* inner */ still */ fn");
        assert_eq!(ks.len(), 2);
        assert_eq!(ks[0].0, Kind::Comment);
        assert!(ks[1].1 == "fn");
    }

    #[test]
    fn idents_starting_with_r_and_b() {
        let ks = kinds("let broken = result; break;");
        assert!(ks.iter().any(|(k, t)| *k == Kind::Ident && t == "broken"));
        assert!(ks.iter().any(|(k, t)| *k == Kind::Ident && t == "break"));
    }

    #[test]
    fn string_line_continuations_advance_the_line_counter() {
        let src = "let s = \"a \\\n   b\";\nlet t = 1;";
        let toks = lex(src);
        let t = toks.iter().find(|t| t.is_ident("t")).unwrap();
        assert_eq!(t.line, 3);
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let ks = kinds("for i in 0..10 { let x = 1.5; }");
        assert!(ks.iter().any(|(k, t)| *k == Kind::Num && t == "0"));
        assert!(ks.iter().any(|(k, t)| *k == Kind::Num && t == "10"));
        assert!(ks.iter().any(|(k, t)| *k == Kind::Num && t == "1.5"));
    }
}
