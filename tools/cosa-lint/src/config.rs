//! `lock_order.toml` loading — a purpose-built TOML subset so the
//! crate stays zero-dependency.  Supported grammar: `#` comments,
//! `[section]` headers, `[[level]]` array-of-tables headers, and
//! `key = "string"` / `key = ["a", "b", ...]` pairs (arrays may span
//! lines).  Anything else is a hard error: the config is part of the
//! gate, so a typo must fail loudly, not parse as an empty rule set.

use std::path::Path;

/// One level of the declared lock hierarchy, outermost-first.
#[derive(Debug, Clone)]
pub struct Level {
    pub name: String,
    /// Receiver-path components matched lexically against
    /// `.lock()/.read()/.write()` receivers.
    pub receivers: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct Config {
    pub families: Vec<String>,
    pub levels: Vec<Level>,
}

/// Removing any of these from `[rules] families` is a config error
/// (exit 2), so CI fails when a rule family is switched off.
pub const REQUIRED_FAMILIES: [&str; 6] = [
    "unsafe-audit",
    "panic-freedom",
    "lock-order",
    "lock-nesting",
    "hot-path-alloc",
    "condvar-wait",
];

fn strip_line(raw: &str) -> &str {
    match raw.find('#') {
        Some(p) => raw[..p].trim(),
        None => raw.trim(),
    }
}

fn quoted_items(val: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut inside = false;
    for chunk in val.split('"') {
        if inside {
            out.push(chunk.to_string());
        }
        inside = !inside;
    }
    out
}

impl Config {
    pub fn load(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Config::parse(&text)
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Config, String> {
        let lines: Vec<&str> = text.lines().collect();
        let mut families: Vec<String> = Vec::new();
        let mut levels: Vec<Level> = Vec::new();
        let mut section = String::new();
        let mut i = 0usize;
        while i < lines.len() {
            let lineno = i + 1;
            let ln = strip_line(lines[i]).to_string();
            i += 1;
            if ln.is_empty() {
                continue;
            }
            if ln == "[[level]]" {
                levels.push(Level {
                    name: String::new(),
                    receivers: Vec::new(),
                });
                section = "level".to_string();
                continue;
            }
            if ln.starts_with('[') {
                section =
                    ln.trim_matches(|c| c == '[' || c == ']').to_string();
                continue;
            }
            let eq = ln.find('=').ok_or_else(|| {
                format!("line {lineno}: expected `key = value`")
            })?;
            let key = ln[..eq].trim().to_string();
            let mut val = ln[eq + 1..].trim().to_string();
            if val.starts_with('[') {
                while !val.contains(']') && i < lines.len() {
                    val.push(' ');
                    val.push_str(strip_line(lines[i]));
                    i += 1;
                }
                if !val.contains(']') {
                    return Err(format!(
                        "line {lineno}: unterminated array for `{key}`"
                    ));
                }
                let items = quoted_items(&val);
                match (section.as_str(), key.as_str()) {
                    ("rules", "families") => families = items,
                    ("level", "receivers") => {
                        match levels.last_mut() {
                            Some(l) => l.receivers = items,
                            None => {
                                return Err(format!(
                                    "line {lineno}: `receivers` outside \
                                     [[level]]"
                                ))
                            }
                        }
                    }
                    _ => {}
                }
            } else if val.starts_with('"') {
                let s = val.trim_matches('"').to_string();
                if section == "level" && key == "name" {
                    match levels.last_mut() {
                        Some(l) => l.name = s,
                        None => {
                            return Err(format!(
                                "line {lineno}: `name` outside [[level]]"
                            ))
                        }
                    }
                }
            } else {
                return Err(format!(
                    "line {lineno}: unsupported value `{val}` (this \
                     config reader takes strings and string arrays only)"
                ));
            }
        }
        for fam in REQUIRED_FAMILIES {
            if !families.iter().any(|f| f == fam) {
                return Err(format!(
                    "rule family `{fam}` missing from [rules] families — \
                     removing a family disables the gate, which is \
                     exactly what this check exists to catch"
                ));
            }
        }
        if levels.len() < 2 {
            return Err(
                "lock hierarchy needs at least two [[level]] tables"
                    .to_string(),
            );
        }
        Ok(Config { families, levels })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
[rules]
families = [
    "unsafe-audit",
    "panic-freedom",
    "lock-order",
    "lock-nesting",
    "hot-path-alloc",
    "condvar-wait",
]

[[level]]
name = "outer"
receivers = ["server"]

[[level]]
name = "inner"
receivers = ["model", "mdl"]
"#;

    #[test]
    fn parses_levels_in_order() {
        let cfg = Config::parse(GOOD).unwrap();
        assert_eq!(cfg.levels.len(), 2);
        assert_eq!(cfg.levels[0].name, "outer");
        assert_eq!(cfg.levels[1].receivers, vec!["model", "mdl"]);
    }

    #[test]
    fn missing_family_is_an_error() {
        let bad = GOOD.replace("\"panic-freedom\",", "");
        let err = Config::parse(&bad).unwrap_err();
        assert!(err.contains("panic-freedom"), "err: {err}");
    }

    #[test]
    fn too_few_levels_is_an_error() {
        let bad = GOOD.split("[[level]]").next().unwrap().to_string();
        assert!(Config::parse(&bad).is_err());
    }
}
