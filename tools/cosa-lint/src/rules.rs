//! The six rule families, all lexical by design: cosa-lint never
//! type-checks — it enforces *textual* invariants that survive
//! refactors (a `// SAFETY:` comment travels with its `unsafe`, a
//! lock receiver keeps its field name) and fails closed on the
//! patterns it cannot see.  See README "Static analysis gates" for
//! the rule semantics and the `// lint:` annotation grammar.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::config::Config;
use crate::lexer::{lex, Kind, Tok};

#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule,
               self.msg)
    }
}

// ---------------------------------------------------------- helpers

fn next_sig(toks: &[Tok], mut i: usize) -> Option<usize> {
    while i < toks.len() {
        if toks[i].kind != Kind::Comment {
            return Some(i);
        }
        i += 1;
    }
    None
}

fn prev_sig(toks: &[Tok], i: usize) -> Option<usize> {
    let mut k = i;
    while k > 0 {
        k -= 1;
        if toks[k].kind != Kind::Comment {
            return Some(k);
        }
    }
    None
}

fn punct_at(toks: &[Tok], i: Option<usize>, ch: char) -> bool {
    matches!(i, Some(j) if toks[j].is_punct(ch))
}

/// Forward scan from an opening delimiter to its match.
fn match_fwd(toks: &[Tok], mut i: usize, open: char, close: char) -> usize {
    let mut depth = 0i64;
    while i < toks.len() {
        if toks[i].is_punct(open) {
            depth += 1;
        } else if toks[i].is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Backward scan from a closing delimiter to its match.
fn match_back(toks: &[Tok], mut i: usize, open: char, close: char) -> usize {
    let mut depth = 0i64;
    loop {
        if toks[i].is_punct(close) {
            depth += 1;
        } else if toks[i].is_punct(open) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        if i == 0 {
            return 0;
        }
        i -= 1;
    }
}

fn in_spans(i: usize, spans: &[(usize, usize)]) -> bool {
    spans.iter().any(|&(a, b)| a <= i && i <= b)
}

fn line_map(toks: &[Tok]) -> BTreeMap<u32, Vec<usize>> {
    let mut lm: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
    for (idx, t) in toks.iter().enumerate() {
        for l in t.line..=t.end_line {
            lm.entry(l).or_default().push(idx);
        }
    }
    lm
}

/// Token ranges covered by `#[cfg(test)]` items (the attribute's
/// following brace block).
fn test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if toks[i].is_punct('#') && i + 1 < n {
            let mut j = i + 1;
            if toks[j].is_punct('!') {
                j += 1;
            }
            if j < n && toks[j].is_punct('[') {
                let mut depth = 1i64;
                let mut k = j + 1;
                let mut idents: Vec<&str> = Vec::new();
                while k < n && depth > 0 {
                    let t = &toks[k];
                    if t.is_punct('[') {
                        depth += 1;
                    } else if t.is_punct(']') {
                        depth -= 1;
                    } else if t.kind == Kind::Ident {
                        idents.push(&t.text);
                    }
                    k += 1;
                }
                if idents.contains(&"cfg") && idents.contains(&"test") {
                    let mut m = k;
                    while m < n {
                        if toks[m].is_punct(';') {
                            break;
                        }
                        if toks[m].is_punct('{') {
                            spans.push((m, match_fwd(toks, m, '{', '}')));
                            break;
                        }
                        m += 1;
                    }
                }
                i = k;
                continue;
            }
        }
        i += 1;
    }
    spans
}

struct FnSpan {
    name: String,
    /// Index of the `fn` keyword token.
    ftok: usize,
    /// Index of the body `{`.
    b0: usize,
    /// Index of the matching `}`.
    b1: usize,
}

fn fn_spans(toks: &[Tok]) -> Vec<FnSpan> {
    let mut res = Vec::new();
    let n = toks.len();
    for i in 0..n {
        if !toks[i].is_ident("fn") {
            continue;
        }
        let Some(j) = next_sig(toks, i + 1) else { continue };
        if toks[j].kind != Kind::Ident {
            continue; // `fn(..)` pointer type, not an item
        }
        let name = toks[j].text.clone();
        let mut k = j + 1;
        let mut pd = 0i64;
        while k < n {
            let t = &toks[k];
            if t.is_punct('(') || t.is_punct('[') {
                pd += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                pd -= 1;
            } else if t.is_punct(';') && pd == 0 {
                break; // trait method declaration without a body
            } else if t.is_punct('{') && pd == 0 {
                res.push(FnSpan {
                    name,
                    ftok: i,
                    b0: k,
                    b1: match_fwd(toks, k, '{', '}'),
                });
                break;
            }
            k += 1;
        }
    }
    res
}

// ------------------------------------------------------- directives

const KNOWN_RULES: [&str; 5] =
    ["panic", "alloc", "lock", "unsafe", "condvar"];

/// Strip comment sigils: `// `, `/* */`, `///`, `//!`, leading `*`s.
fn strip_comment(text: &str) -> &str {
    let mut t = text;
    if let Some(s) = t.strip_prefix("/*") {
        t = s.strip_suffix("*/").unwrap_or(s);
    }
    t.trim_start_matches(|c| matches!(c, '/' | '*' | '!' | ' ' | '\t'))
}

fn is_safety(text: &str) -> bool {
    strip_comment(text).lines().any(|ln| {
        ln.trim()
            .trim_start_matches(|c| {
                matches!(c, '/' | '*' | '!' | ' ' | '\t')
            })
            .starts_with("SAFETY:")
    })
}

#[derive(Default)]
struct Directives {
    file_allows: HashSet<String>,
    line_allows: HashMap<String, HashSet<u32>>,
    hot_path: bool,
    setup_marks: Vec<usize>,
}

impl Directives {
    fn allowed(&self, rule: &str, line: u32) -> bool {
        self.file_allows.contains(rule)
            || self
                .line_allows
                .get(rule)
                .is_some_and(|s| s.contains(&line))
    }
}

/// `allow(rule) reason` / `allow-file(rule) reason` after `lint:`.
fn parse_allow(rest: &str) -> Option<(bool, String, String)> {
    let (filewide, tail) = if let Some(t) = rest.strip_prefix("allow-file(")
    {
        (true, t)
    } else if let Some(t) = rest.strip_prefix("allow(") {
        (false, t)
    } else {
        return None;
    };
    let close = tail.find(')')?;
    let rule = tail[..close].trim().to_string();
    if rule.is_empty()
        || !rule
            .chars()
            .all(|c| c.is_alphanumeric() || c == '-' || c == '_')
    {
        return None;
    }
    Some((filewide, rule, tail[close + 1..].to_string()))
}

fn clean_reason(raw: &str) -> String {
    raw.trim()
        .trim_start_matches(|c| {
            matches!(c, '\u{2014}' | '\u{2013}' | ':' | '-' | ' ' | '\t')
        })
        .trim()
        .to_string()
}

fn parse_directives(
    toks: &[Tok],
    findings: &mut Vec<Finding>,
    path: &str,
) -> Directives {
    let mut d = Directives::default();
    let first_code = next_sig(toks, 0).unwrap_or(toks.len());
    for (idx, t) in toks.iter().enumerate() {
        if t.kind != Kind::Comment {
            continue;
        }
        let body = strip_comment(&t.text).trim();
        let Some(rest) = body.strip_prefix("lint:") else { continue };
        let rest = rest.trim();
        if rest == "hot-path" {
            if idx < first_code {
                d.hot_path = true;
            } else {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "allowlist",
                    msg: "`lint: hot-path` must precede all code (put \
                          it in the file header)"
                        .to_string(),
                });
            }
            continue;
        }
        if rest == "setup" {
            d.setup_marks.push(idx);
            continue;
        }
        let Some((filewide, rule, raw_reason)) = parse_allow(rest) else {
            findings.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: "allowlist",
                msg: format!("unrecognized `lint:` directive `{rest}`"),
            });
            continue;
        };
        if !KNOWN_RULES.contains(&rule.as_str()) {
            findings.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: "allowlist",
                msg: format!(
                    "unknown rule `{rule}` in allow (expected one of \
                     {KNOWN_RULES:?})"
                ),
            });
            continue;
        }
        if clean_reason(&raw_reason).is_empty() {
            findings.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: "allowlist",
                msg: format!(
                    "allow({rule}) without a reason — write `// lint: \
                     allow({rule}) — <why>`"
                ),
            });
            continue;
        }
        if filewide {
            if idx < first_code {
                d.file_allows.insert(rule);
            } else {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "allowlist",
                    msg: "allow-file must precede all code".to_string(),
                });
            }
        } else {
            let s = d.line_allows.entry(rule).or_default();
            s.insert(t.line);
            s.insert(t.end_line + 1);
        }
    }
    d
}

// ----------------------------------------------- rule: unsafe-audit

/// Walk backwards from `unsafe`, skipping attribute groups,
/// visibility qualifiers, and comments, looking for a `// SAFETY:`.
fn backward_safety(toks: &[Tok], i: usize) -> bool {
    let mut k = i as i64 - 1;
    while k >= 0 {
        let t = &toks[k as usize];
        if t.kind == Kind::Comment {
            if is_safety(&t.text) {
                return true;
            }
            k -= 1;
            continue;
        }
        if t.is_punct(']') {
            let mut m = match_back(toks, k as usize, '[', ']') as i64 - 1;
            if m >= 0 && toks[m as usize].is_punct('!') {
                m -= 1;
            }
            if m >= 0 && toks[m as usize].is_punct('#') {
                k = m - 1;
                continue;
            }
            return false;
        }
        if t.kind == Kind::Ident
            && matches!(t.text.as_str(), "pub" | "const" | "extern")
        {
            k -= 1;
            continue;
        }
        if t.is_punct(')') {
            // `pub(crate)` and friends
            let m = match_back(toks, k as usize, '(', ')');
            match prev_sig(toks, m) {
                Some(p) if toks[p].is_ident("pub") => {
                    k = p as i64 - 1;
                    continue;
                }
                _ => return false,
            }
        }
        return false;
    }
    false
}

/// Accept a SAFETY comment on the contiguous run of comment-only (or
/// attribute) lines directly above — covers `let x = unsafe { .. }`
/// where the comment sits above the whole statement.
fn lines_above_safety(
    toks: &[Tok],
    lm: &BTreeMap<u32, Vec<usize>>,
    start_line: u32,
) -> bool {
    let mut l = start_line.saturating_sub(1);
    while l >= 1 {
        let Some(idxs) = lm.get(&l) else { return false };
        if idxs.iter().all(|&k| toks[k].kind == Kind::Comment) {
            if idxs.iter().any(|&k| is_safety(&toks[k].text)) {
                return true;
            }
            l -= 1;
            continue;
        }
        if toks[idxs[0]].is_punct('#') {
            l -= 1;
            continue;
        }
        return false;
    }
    false
}

fn rule_unsafe(
    toks: &[Tok],
    lm: &BTreeMap<u32, Vec<usize>>,
    d: &Directives,
    findings: &mut Vec<Finding>,
    path: &str,
) {
    let n = toks.len();
    for i in 0..n {
        let t = &toks[i];
        if !t.is_ident("unsafe") || d.allowed("unsafe", t.line) {
            continue;
        }
        let mut ok = false;
        // `unsafe { // SAFETY: ... }` — comment as first block token.
        if let Some(j) = next_sig(toks, i + 1) {
            if toks[j].is_punct('{')
                && j + 1 < n
                && toks[j + 1].kind == Kind::Comment
                && is_safety(&toks[j + 1].text)
            {
                ok = true;
            }
        }
        if !ok {
            ok = backward_safety(toks, i);
        }
        if !ok {
            ok = lines_above_safety(toks, lm, t.line);
        }
        if !ok {
            findings.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: "unsafe-audit",
                msg: "`unsafe` without an immediately preceding \
                      `// SAFETY:` comment"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------- rule: panic-freedom

const PANIC_MACROS: [&str; 4] =
    ["panic", "unreachable", "todo", "unimplemented"];

fn rule_panic(
    toks: &[Tok],
    tspans: &[(usize, usize)],
    d: &Directives,
    findings: &mut Vec<Finding>,
    path: &str,
) {
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != Kind::Ident || in_spans(i, tspans) {
            continue;
        }
        let name = t.text.as_str();
        if name == "unwrap" || name == "expect" {
            let p = prev_sig(toks, i);
            let nx = next_sig(toks, i + 1);
            if punct_at(toks, p, '.')
                && punct_at(toks, nx, '(')
                && !d.allowed("panic", t.line)
            {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "panic-freedom",
                    msg: format!(
                        "`.{name}()` in a request-path module (convert \
                         to error propagation or `// lint: \
                         allow(panic) — <why>`)"
                    ),
                });
            }
        } else if PANIC_MACROS.contains(&name) {
            let nx = next_sig(toks, i + 1);
            if punct_at(toks, nx, '!') && !d.allowed("panic", t.line) {
                findings.push(Finding {
                    file: path.to_string(),
                    line: t.line,
                    rule: "panic-freedom",
                    msg: format!(
                        "`{name}!` in a request-path module"
                    ),
                });
            }
        }
    }
}

// ------------------------------------- rule: lock-order + hygiene

/// The receiver path left of a `.lock()` dot: `self.stats.by_adapter`
/// → `["self", "stats", "by_adapter"]`.  Method calls and index
/// expressions in the chain are traversed (`self.inner().lock()`,
/// `queues[c].lock()`).
fn receiver_chain(toks: &[Tok], dot_idx: usize) -> Vec<String> {
    let mut comps: Vec<String> = Vec::new();
    let mut k = prev_sig(toks, dot_idx);
    while let Some(ki) = k {
        let t = &toks[ki];
        if t.is_punct(')') {
            let m = match_back(toks, ki, '(', ')');
            k = prev_sig(toks, m);
            continue;
        }
        if t.is_punct(']') {
            let m = match_back(toks, ki, '[', ']');
            match prev_sig(toks, m) {
                Some(p) if toks[p].kind == Kind::Ident => k = Some(p),
                _ => break,
            }
            continue;
        }
        if t.kind == Kind::Ident {
            comps.push(t.text.clone());
            let p = prev_sig(toks, ki);
            if punct_at(toks, p, '.') {
                k = prev_sig(toks, p.unwrap_or(0));
                continue;
            }
            if punct_at(toks, p, ':') {
                let p2 = prev_sig(toks, p.unwrap_or(0));
                if punct_at(toks, p2, ':') {
                    k = prev_sig(toks, p2.unwrap_or(0));
                    continue;
                }
            }
            break;
        }
        break;
    }
    comps.reverse();
    comps
}

/// Detect a lock acquisition at ident `i`.  Returns the receiver
/// components and the index just past the call's closing paren.
fn detect_acquisition(
    toks: &[Tok],
    i: usize,
) -> Option<(Vec<String>, usize)> {
    let tx = toks[i].text.as_str();
    if !matches!(tx, "lock" | "read" | "write") {
        return None;
    }
    let p = prev_sig(toks, i);
    let o = next_sig(toks, i + 1)?;
    if !toks[o].is_punct('(') {
        return None;
    }
    if punct_at(toks, p, '.') {
        let c = next_sig(toks, o + 1)?;
        if !toks[c].is_punct(')') {
            return None; // has args → io::Read::read etc., not a lock
        }
        let dot = p.unwrap_or(0);
        return Some((receiver_chain(toks, dot), c + 1));
    }
    if tx == "lock" {
        // The scheduler's free-fn poison-recovering helper:
        // `lock(&self.rx)`.  Skip the helper's own definition and any
        // path-qualified call.
        if let Some(pi) = p {
            if toks[pi].is_ident("fn")
                || toks[pi].is_punct('.')
                || toks[pi].is_punct(':')
            {
                return None;
            }
        }
        let close = match_fwd(toks, o, '(', ')');
        let comps: Vec<String> = toks[o + 1..close]
            .iter()
            .filter(|t| t.kind == Kind::Ident && t.text != "mut")
            .map(|t| t.text.clone())
            .collect();
        if comps.is_empty() {
            return None;
        }
        return Some((comps, close + 1));
    }
    None
}

fn classify<'c>(
    comps: &[String],
    cfg: &'c Config,
) -> Option<(usize, &'c str)> {
    for c in comps.iter().rev() {
        for (rank, lvl) in cfg.levels.iter().enumerate() {
            if lvl.receivers.iter().any(|r| r == c) {
                return Some((rank, &lvl.name));
            }
        }
    }
    None
}

struct Guard {
    rank: usize,
    lname: String,
    recv: String,
    /// `Some(v)` when bound by `let v = ...` (lives until `drop(v)`
    /// or block end); `None` for statement temporaries.
    var: Option<String>,
    adepth: i64,
    line: u32,
}

/// Calls whose result is still the guard (`.lock().unwrap_or_else(..)`
/// hands the guard through); anything else chained after an
/// acquisition consumes the guard within the statement.
const GUARD_ADAPTERS: [&str; 4] =
    ["unwrap", "expect", "unwrap_or_else", "unwrap_or_default"];

/// One same-level nested acquisition observed while a same-level
/// guard with a *different* receiver was live: (held receiver,
/// acquired receiver, level name, line of the inner acquisition).
/// The hierarchy check cannot order these — `rule_lock` reconciles
/// them per file and flags pairs nested in opposite orders.
type NestPair = (String, String, String, u32);

/// Condvar parking calls.  Each releases exactly ONE lock — the guard
/// it is passed — for the duration of the sleep; any other guard the
/// thread holds stays locked while it sleeps, starving contenders.
/// Arg-less `.wait()` (tickets, child processes) is out of scope: the
/// rule keys on a guard being handed to the condvar.
const CONDVAR_WAITS: [&str; 3] = ["wait", "wait_timeout", "wait_while"];

#[allow(clippy::too_many_arguments)]
fn analyze_fn(
    toks: &[Tok],
    b0: usize,
    b1: usize,
    nested: &[(usize, usize)],
    cfg: &Config,
    d: &Directives,
    nests: &mut Vec<NestPair>,
    findings: &mut Vec<Finding>,
    path: &str,
) {
    let mut depth = 0i64;
    let mut guards: Vec<Guard> = Vec::new();
    let mut pending_let: Option<(String, i64)> = None;
    let mut i = b0;
    while i <= b1 && i < toks.len() {
        if let Some(&(_, e)) = nested.iter().find(|&&(s, _)| s == i) {
            i = e + 1;
            continue;
        }
        let t = &toks[i];
        match t.kind {
            Kind::Comment => {
                i += 1;
                continue;
            }
            Kind::Punct => {
                if t.is_punct('{') {
                    depth += 1;
                } else if t.is_punct('}') {
                    depth -= 1;
                    guards.retain(|g| g.adepth <= depth);
                    if pending_let.as_ref().is_some_and(|p| p.1 > depth) {
                        pending_let = None;
                    }
                } else if t.is_punct(';') {
                    guards.retain(|g| {
                        !(g.var.is_none() && g.adepth >= depth)
                    });
                    if pending_let.as_ref().is_some_and(|p| p.1 == depth)
                    {
                        pending_let = None;
                    }
                }
                i += 1;
                continue;
            }
            Kind::Ident => {}
            _ => {
                i += 1;
                continue;
            }
        }
        let tx = t.text.as_str();
        if tx == "let" {
            let mut j = next_sig(toks, i + 1);
            if let Some(ji) = j {
                if toks[ji].is_ident("mut") {
                    j = next_sig(toks, ji + 1);
                }
            }
            if let Some(ji) = j {
                if toks[ji].kind == Kind::Ident {
                    pending_let = Some((toks[ji].text.clone(), depth));
                }
            }
            i += 1;
            continue;
        }
        if tx == "drop" {
            if let Some(j) = next_sig(toks, i + 1) {
                if toks[j].is_punct('(') {
                    if let Some(j2) = next_sig(toks, j + 1) {
                        if toks[j2].kind == Kind::Ident {
                            let vn = toks[j2].text.clone();
                            guards.retain(|g| {
                                g.var.as_deref() != Some(vn.as_str())
                            });
                        }
                    }
                }
            }
            i += 1;
            continue;
        }
        if let Some((comps, after)) = detect_acquisition(toks, i) {
            if let Some((rank, lname)) = classify(&comps, cfg) {
                let recv = comps.join(".");
                for g in &guards {
                    if rank < g.rank && !d.allowed("lock", t.line) {
                        findings.push(Finding {
                            file: path.to_string(),
                            line: t.line,
                            rule: "lock-order",
                            msg: format!(
                                "acquired `{lname}` lock (`{recv}`) \
                                 while holding `{}` lock (`{}`, line \
                                 {}) — hierarchy is outermost-first \
                                 in lock_order.toml",
                                g.lname, g.recv, g.line
                            ),
                        });
                    }
                    // Same-level nesting is legal on its own (levels
                    // only order *across* levels) — record the order
                    // so the per-file reconciliation can catch two
                    // fns nesting the same pair both ways (ABBA).
                    if rank == g.rank
                        && g.recv != recv
                        && !d.allowed("lock", t.line)
                    {
                        nests.push((
                            g.recv.clone(),
                            recv.clone(),
                            lname.to_string(),
                            t.line,
                        ));
                    }
                }
                // Skip guard-preserving adapters, then decide whether
                // the guard is let-bound or a statement temporary.
                let mut j = after;
                let mut jj = next_sig(toks, j);
                loop {
                    if punct_at(toks, jj, '.') {
                        let nm = next_sig(toks, jj.unwrap_or(0) + 1);
                        if let Some(nmi) = nm {
                            if toks[nmi].kind == Kind::Ident
                                && GUARD_ADAPTERS
                                    .contains(&toks[nmi].text.as_str())
                            {
                                if let Some(op) = next_sig(toks, nmi + 1)
                                {
                                    if toks[op].is_punct('(') {
                                        j = match_fwd(
                                            toks, op, '(', ')',
                                        ) + 1;
                                        jj = next_sig(toks, j);
                                        continue;
                                    }
                                }
                            }
                        }
                    }
                    break;
                }
                let chained = punct_at(toks, jj, '.');
                let var = if !chained {
                    pending_let
                        .as_ref()
                        .filter(|p| p.1 == depth)
                        .map(|p| p.0.clone())
                } else {
                    None
                };
                guards.push(Guard {
                    rank,
                    lname: lname.to_string(),
                    recv,
                    var,
                    adepth: depth,
                    line: t.line,
                });
            }
            i += 1;
            continue;
        }
        if !guards.is_empty() && CONDVAR_WAITS.contains(&tx) {
            let p = prev_sig(toks, i);
            let nx = next_sig(toks, i + 1);
            if punct_at(toks, p, '.') && punct_at(toks, nx, '(') {
                let open = nx.unwrap_or(i);
                let close = match_fwd(toks, open, '(', ')');
                // The guard handed to the condvar — the one lock the
                // wait actually releases while the thread sleeps.
                let waited: Option<&str> = toks[open + 1..close]
                    .iter()
                    .find(|a| a.kind == Kind::Ident && a.text != "mut")
                    .map(|a| a.text.as_str());
                if waited.is_some() {
                    for g in &guards {
                        let released = g.var.as_deref() == waited;
                        if !released && !d.allowed("condvar", t.line) {
                            findings.push(Finding {
                                file: path.to_string(),
                                line: t.line,
                                rule: "condvar-wait",
                                msg: format!(
                                    "`.{tx}()` parks the thread while \
                                     still holding the `{}` lock \
                                     (`{}`, line {}) — a condvar wait \
                                     releases only the guard it is \
                                     passed",
                                    g.lname, g.recv, g.line
                                ),
                            });
                        }
                    }
                }
            }
            i += 1;
            continue;
        }
        if !guards.is_empty() {
            let held = &guards[guards.len() - 1];
            if tx == "File" {
                let nx = next_sig(toks, i + 1);
                if punct_at(toks, nx, ':') && !d.allowed("lock", t.line) {
                    findings.push(Finding {
                        file: path.to_string(),
                        line: t.line,
                        rule: "lock-hygiene",
                        msg: format!(
                            "`File::` I/O while holding the `{}` lock \
                             (`{}`, line {})",
                            held.lname, held.recv, held.line
                        ),
                    });
                }
            } else if tx.starts_with("read_") || tx.starts_with("regen_")
            {
                let nx = next_sig(toks, i + 1);
                if punct_at(toks, nx, '(') && !d.allowed("lock", t.line)
                {
                    findings.push(Finding {
                        file: path.to_string(),
                        line: t.line,
                        rule: "lock-hygiene",
                        msg: format!(
                            "`{tx}()` (I/O / regen) while holding the \
                             `{}` lock (`{}`, line {})",
                            held.lname, held.recv, held.line
                        ),
                    });
                }
            }
        }
        i += 1;
    }
}

fn rule_lock(
    toks: &[Tok],
    tspans: &[(usize, usize)],
    fns: &[FnSpan],
    cfg: &Config,
    d: &Directives,
    findings: &mut Vec<Finding>,
    path: &str,
) {
    let mut nests: Vec<NestPair> = Vec::new();
    for f in fns {
        if in_spans(f.b0, tspans) {
            continue;
        }
        let nested: Vec<(usize, usize)> = fns
            .iter()
            .filter(|g| g.b0 > f.b0 && g.b1 < f.b1)
            .map(|g| (g.b0, g.b1))
            .collect();
        analyze_fn(toks, f.b0, f.b1, &nested, cfg, d, &mut nests,
                   findings, path);
    }
    // Per-file reconciliation of same-level nesting orders: fn A
    // taking `q` then `queue` and fn B taking `queue` then `q` is a
    // classic ABBA deadlock the rank check is blind to (both pass the
    // hierarchy).  One finding per conflicting receiver pair, on the
    // first line each direction was seen.
    let mut first: BTreeMap<(String, String), (String, u32)> =
        BTreeMap::new();
    for (outer, inner, lname, line) in nests {
        first.entry((outer, inner)).or_insert((lname, line));
    }
    for ((a, b), (lname, line)) in &first {
        if a >= b {
            continue; // visit each unordered pair once
        }
        if let Some((_, rline)) = first.get(&(b.clone(), a.clone())) {
            findings.push(Finding {
                file: path.to_string(),
                line: *line.min(rline),
                rule: "lock-nesting",
                msg: format!(
                    "same-level `{lname}` locks nested in opposite \
                     orders: `{a}` before `{b}` (line {line}) but \
                     `{b}` before `{a}` (line {rline}) — ABBA \
                     deadlock; pick one order (or `// lint: \
                     allow(lock) — <why>` on an acquisition)"
                ),
            });
        }
    }
}

// ------------------------------------------ rule: hot-path allocs

const SETUP_PREFIXES: [&str; 7] =
    ["new_", "with_", "from_", "setup", "init", "prepare", "prealloc"];

fn is_setup_name(name: &str) -> bool {
    name == "new"
        || name == "default"
        || SETUP_PREFIXES.iter().any(|p| name.starts_with(p))
}

fn rule_alloc(
    toks: &[Tok],
    tspans: &[(usize, usize)],
    fns: &[FnSpan],
    d: &Directives,
    findings: &mut Vec<Finding>,
    path: &str,
) {
    if !d.hot_path {
        return;
    }
    let mut setup_ranges: Vec<(usize, usize)> = Vec::new();
    for f in fns {
        let marked = d.setup_marks.iter().any(|&m| {
            m < f.ftok
                && fns.iter().all(|g| !(m < g.ftok && g.ftok < f.ftok))
        });
        if is_setup_name(&f.name) || marked {
            setup_ranges.push((f.b0, f.b1));
        }
    }
    let n = toks.len();
    let mut flag =
        |findings: &mut Vec<Finding>, line: u32, what: &str| {
            if !d.allowed("alloc", line) {
                findings.push(Finding {
                    file: path.to_string(),
                    line,
                    rule: "hot-path-alloc",
                    msg: format!(
                        "`{what}` in a `lint: hot-path` file outside a \
                         setup fn"
                    ),
                });
            }
        };
    for i in 0..n {
        let t = &toks[i];
        if t.kind != Kind::Ident
            || in_spans(i, tspans)
            || in_spans(i, &setup_ranges)
        {
            continue;
        }
        let tx = t.text.as_str();
        if tx == "Vec" || tx == "Box" {
            let a = next_sig(toks, i + 1);
            if !punct_at(toks, a, ':') {
                continue;
            }
            let b = next_sig(toks, a.unwrap_or(0) + 1);
            if !punct_at(toks, b, ':') {
                continue;
            }
            let c = next_sig(toks, b.unwrap_or(0) + 1);
            if let Some(ci) = c {
                if toks[ci].is_ident("new") {
                    let o = next_sig(toks, ci + 1);
                    if punct_at(toks, o, '(') {
                        flag(findings, t.line, &format!("{tx}::new()"));
                    }
                }
            }
        } else if tx == "vec" {
            let a = next_sig(toks, i + 1);
            if punct_at(toks, a, '!') {
                flag(findings, t.line, "vec![]");
            }
        } else if tx == "to_vec" || tx == "collect" {
            let p = prev_sig(toks, i);
            if !punct_at(toks, p, '.') {
                continue;
            }
            let a = next_sig(toks, i + 1);
            if punct_at(toks, a, '(') {
                flag(findings, t.line, &format!(".{tx}()"));
            } else if punct_at(toks, a, ':') {
                let b = next_sig(toks, a.unwrap_or(0) + 1);
                if !punct_at(toks, b, ':') {
                    continue;
                }
                let c = next_sig(toks, b.unwrap_or(0) + 1);
                if punct_at(toks, c, '<') {
                    // skip the turbofish
                    let mut depth = 1i64;
                    let mut k = c.unwrap_or(0) + 1;
                    while k < n && depth > 0 {
                        if toks[k].is_punct('<') {
                            depth += 1;
                        } else if toks[k].is_punct('>') {
                            depth -= 1;
                        }
                        k += 1;
                    }
                    let o = next_sig(toks, k);
                    if punct_at(toks, o, '(') {
                        flag(findings, t.line, &format!(".{tx}::<..>()"));
                    }
                }
            }
        }
    }
}

// ----------------------------------------------------------- driver

/// Lint one file.  `path` decides rule scoping (request-path modules,
/// `tests/` exemption), so callers may pass a virtual path when the
/// source does not live where it is pretended to (fixtures do this).
pub fn check_source(path: &str, src: &str, cfg: &Config) -> Vec<Finding> {
    let mut findings = Vec::new();
    let toks = lex(src);
    let lm = line_map(&toks);
    let tspans = test_spans(&toks);
    let fns = fn_spans(&toks);
    let d = parse_directives(&toks, &mut findings, path);
    let norm = path.replace('\\', "/");
    let comps: Vec<&str> = norm.split('/').collect();
    let dirs = &comps[..comps.len().saturating_sub(1)];
    let in_tests = dirs.iter().any(|c| *c == "tests");
    let request_path = dirs
        .iter()
        .any(|c| {
            matches!(*c, "serve" | "wire" | "model" | "linalg" | "obs")
        });
    rule_unsafe(&toks, &lm, &d, &mut findings, path);
    if request_path && !in_tests {
        rule_panic(&toks, &tspans, &d, &mut findings, path);
    }
    rule_lock(&toks, &tspans, &fns, cfg, &d, &mut findings, path);
    rule_alloc(&toks, &tspans, &fns, &d, &mut findings, path);
    findings
}
