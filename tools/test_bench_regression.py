#!/usr/bin/env python3
"""Unit tests for the bench_regression gate, runnable with no test
framework beyond the standard library:

    python3 tools/test_bench_regression.py

They feed synthetic reports to the check functions (and one end-to-end
main() run over temp files) so a gate regression — a renamed key
silently disabling a check, a ratio gate that stopped failing — is
caught without needing a Rust toolchain or a bench run.
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_regression as br  # noqa: E402


def tail_row(**over):
    """A healthy serving_tail row at the acceptance shape."""
    row = {
        "sites": 24,
        "adapters": 512,
        "zipf": 1.0,
        "throughput_rps": 4000.0,
        "p99_ms": 30.0,
        "fused_vs_per_adapter": 3.0,
    }
    row.update(over)
    return row


TAIL_BASE = {
    "serving_tail": {
        "throughput_rps_floor": 100.0,
        "p99_ms_ceiling": 5000.0,
        "min_fused_vs_per_adapter": 1.5,
        "sites": 24,
        "adapters": 512,
        "zipf": 1.0,
    }
}


class TailGate(unittest.TestCase):
    def check(self, rows, base=TAIL_BASE, require=True):
        failures = []
        br.check_serving_tail(rows, base, "BENCH_baseline.json",
                              require, failures)
        return failures

    def test_healthy_row_passes(self):
        self.assertEqual(self.check([tail_row()]), [])

    def test_low_fused_ratio_fails(self):
        failures = self.check([tail_row(fused_vs_per_adapter=1.2)])
        self.assertEqual(len(failures), 1)
        self.assertIn("fused/per-adapter", failures[0])

    def test_ratio_gate_defaults_to_1_5_without_baseline(self):
        # No baseline floors at all: the machine-independent ratio gate
        # must still enforce its built-in 1.5x default.
        failures = self.check([tail_row(fused_vs_per_adapter=1.2)],
                              base=None)
        self.assertTrue(any("fused/per-adapter" in f for f in failures))
        self.assertEqual(self.check([tail_row()], base=None), [])

    def test_throughput_floor_and_p99_ceiling(self):
        failures = self.check([tail_row(throughput_rps=5.0)])
        self.assertTrue(any("throughput" in f for f in failures))
        failures = self.check([tail_row(p99_ms=9999.0)])
        self.assertTrue(any("p99" in f for f in failures))

    def test_off_shape_rows_are_not_gated(self):
        # A local 8-adapter exploration must not be held to the fleet
        # floors — but then zero gated rows must fail under CI mode.
        rows = [tail_row(adapters=8, fused_vs_per_adapter=0.5)]
        self.assertEqual(self.check(rows, require=False), [])
        failures = self.check(rows, require=True)
        self.assertEqual(len(failures), 1)
        self.assertIn("matched 0 rows", failures[0])

    def test_malformed_baseline_section_fails(self):
        failures = self.check([tail_row()],
                              base={"serving_tail": [tail_row()]})
        self.assertTrue(any("object of floors" in f for f in failures))


def method_row(method, **over):
    """A healthy serving_methods row at the acceptance shape."""
    row = {
        "method": method,
        "sites": 24,
        "adapters": 8,
        "zipf": 1.1,
        "throughput_rps": 900.0,
        "seq_throughput_rps": 400.0,
        "batched_vs_sequential": 2.2,
        "p99_ms": 40.0,
    }
    row.update(over)
    return row


def methods_rows_all(**over):
    return [method_row(m, **over)
            for m in ("cosa", "rosa", "lora", "mixed")]


METHODS_BASE = {
    "serving_methods": {
        "sites": 24,
        "zipf": 1.1,
        "min_batched_vs_sequential": 1.2,
        "throughput_rps_floors": {
            "cosa": 50.0, "rosa": 50.0, "lora": 50.0, "mixed": 50.0,
        },
    }
}


class MethodsGate(unittest.TestCase):
    def check(self, rows, base=METHODS_BASE, require=True):
        failures = []
        br.check_serving_methods(rows, base, "BENCH_baseline.json",
                                 require, failures)
        return failures

    def test_healthy_zoo_passes(self):
        self.assertEqual(self.check(methods_rows_all()), [])

    def test_one_method_below_ratio_gate_fails(self):
        rows = methods_rows_all()
        rows[1]["batched_vs_sequential"] = 1.05  # rosa regressed
        failures = self.check(rows)
        self.assertEqual(len(failures), 1)
        self.assertIn("rosa", failures[0])
        self.assertIn("batching", failures[0])

    def test_ratio_gate_defaults_to_1_2_without_baseline(self):
        # The per-method batching-profit gate is the acceptance
        # criterion — it must hold even with no committed floors.
        rows = methods_rows_all()
        rows[3]["batched_vs_sequential"] = 1.1  # mixed regressed
        failures = self.check(rows, base=None)
        self.assertTrue(any("mixed" in f for f in failures))
        self.assertEqual(self.check(methods_rows_all(), base=None), [])

    def test_per_method_throughput_floor(self):
        rows = methods_rows_all()
        rows[2]["throughput_rps"] = 10.0  # lora below its 50 req/s floor
        failures = self.check(rows)
        self.assertEqual(len(failures), 1)
        self.assertIn("lora", failures[0])
        self.assertIn("floor", failures[0])

    def test_missing_mixed_row_fails(self):
        # The method-interleaved stream is the reason the zoo shares
        # one engine; dropping it must not read as a pass.
        rows = [method_row(m) for m in ("cosa", "rosa", "lora")]
        failures = self.check(rows)
        self.assertTrue(any("`mixed`" in f for f in failures))

    def test_off_shape_rows_are_not_gated(self):
        rows = methods_rows_all(sites=3, batched_vs_sequential=0.5)
        self.assertEqual(self.check(rows, require=False), [])
        failures = self.check(rows, require=True)
        self.assertEqual(len(failures), 1)
        self.assertIn("matched 0 rows", failures[0])

    def test_malformed_baseline_section_fails(self):
        failures = self.check(
            methods_rows_all(),
            base={"serving_methods": methods_rows_all()})
        self.assertTrue(any("object of floors" in f for f in failures))


def quant_row(kind, **over):
    """A healthy serving_quant row at the acceptance shape."""
    row = {
        "kind": kind,
        "sites": 24,
        "adapters": 64,
        "zipf": 1.1,
        "hit_rate": 0.5,
        "hit_rate_vs_f32": 1.0,
        "resident_tensors": 40,
        "capacity_vs_f32": 1.0,
        "resident_bytes": 3000000,
        "rmse_vs_f32": 0.0,
        "throughput_rps": 100.0,
    }
    row.update(over)
    return row


def quant_rows_all():
    return [
        quant_row("f32"),
        quant_row("bf16", capacity_vs_f32=2.0, rmse_vs_f32=0.004),
        quant_row("int8", capacity_vs_f32=3.5, rmse_vs_f32=0.02),
    ]


QUANT_BASE = {
    "serving_quant": {
        "sites": 24,
        "adapters": 64,
        "zipf": 1.1,
        "min_capacity_vs_f32_bf16": 1.8,
        "max_rmse_vs_f32": {"f32": 0.0, "bf16": 0.03, "int8": 0.08},
    }
}


class QuantGate(unittest.TestCase):
    def check(self, rows, base=QUANT_BASE, require=True):
        failures = []
        br.check_serving_quant(rows, base, "BENCH_baseline.json",
                               require, failures)
        return failures

    def test_healthy_codecs_pass(self):
        self.assertEqual(self.check(quant_rows_all()), [])

    def test_low_bf16_capacity_fails(self):
        rows = quant_rows_all()
        rows[1]["capacity_vs_f32"] = 1.3  # bf16 stopped multiplying
        failures = self.check(rows)
        self.assertEqual(len(failures), 1)
        self.assertIn("effective capacity", failures[0])

    def test_rmse_over_budget_fails_per_kind(self):
        rows = quant_rows_all()
        rows[2]["rmse_vs_f32"] = 0.2  # int8 blew its error budget
        failures = self.check(rows)
        self.assertEqual(len(failures), 1)
        self.assertIn("int8", failures[0])
        self.assertIn("error budget", failures[0])

    def test_f32_must_stay_bit_identical(self):
        # Any nonzero f32 RMSE means the default codec path no longer
        # routes through the identity encode — a silent correctness bug.
        rows = quant_rows_all()
        rows[0]["rmse_vs_f32"] = 1e-9
        failures = self.check(rows)
        self.assertEqual(len(failures), 1)
        self.assertIn("f32", failures[0])

    def test_gates_default_without_baseline(self):
        # The capacity and error-budget gates ARE the acceptance
        # criteria — they must hold with no committed baseline object.
        rows = quant_rows_all()
        rows[1]["capacity_vs_f32"] = 1.0
        failures = self.check(rows, base=None)
        self.assertTrue(any("effective capacity" in f for f in failures))
        self.assertEqual(self.check(quant_rows_all(), base=None), [])

    def test_missing_bf16_row_fails(self):
        rows = [quant_row("f32"),
                quant_row("int8", capacity_vs_f32=3.5, rmse_vs_f32=0.02)]
        failures = self.check(rows)
        self.assertTrue(any("`bf16`" in f for f in failures))

    def test_off_shape_rows_are_not_gated(self):
        rows = [quant_row("bf16", adapters=8, capacity_vs_f32=0.5,
                          rmse_vs_f32=9.0)]
        self.assertEqual(self.check(rows, require=False), [])
        failures = self.check(rows, require=True)
        self.assertEqual(len(failures), 1)
        self.assertIn("matched 0 rows", failures[0])

    def test_malformed_baseline_section_fails(self):
        failures = self.check(quant_rows_all(),
                              base={"serving_quant": quant_rows_all()})
        self.assertTrue(any("object of gates" in f for f in failures))


def obs_row(**over):
    """A healthy serving_obs row at the acceptance shape."""
    row = {
        "adapters": 64,
        "requests": 2048,
        "zipf": 1.1,
        "passes": 3,
        "untraced_throughput_rps": 4000.0,
        "traced_throughput_rps": 3920.0,
        "traced_vs_untraced": 0.98,
        "slow_captured": 32,
        "p99_us_gemm": 800,
    }
    row.update(over)
    return row


OBS_BASE = {
    "serving_obs": {
        "adapters": 64,
        "zipf": 1.1,
        "min_traced_vs_untraced": 0.95,
        "throughput_rps_floor": 500.0,
    }
}


class ObsGate(unittest.TestCase):
    def check(self, rows, base=OBS_BASE, require=True):
        failures = []
        br.check_serving_obs(rows, base, "BENCH_baseline.json",
                             require, failures)
        return failures

    def test_healthy_row_passes(self):
        self.assertEqual(self.check([obs_row()]), [])

    def test_low_overhead_ratio_fails(self):
        failures = self.check([obs_row(traced_vs_untraced=0.8)])
        self.assertEqual(len(failures), 1)
        self.assertIn("traced/untraced", failures[0])

    def test_ratio_gate_defaults_to_0_95_without_baseline(self):
        # "Tracing costs < 5%" is the acceptance criterion — it must
        # hold even with no committed baseline object.
        failures = self.check([obs_row(traced_vs_untraced=0.9)],
                              base=None)
        self.assertTrue(any("traced/untraced" in f for f in failures))
        self.assertEqual(self.check([obs_row()], base=None), [])

    def test_traced_throughput_floor(self):
        failures = self.check([obs_row(traced_throughput_rps=100.0,
                                       untraced_throughput_rps=102.0)])
        self.assertEqual(len(failures), 1)
        self.assertIn("floor", failures[0])

    def test_off_shape_rows_are_not_gated(self):
        rows = [obs_row(adapters=8, traced_vs_untraced=0.5)]
        self.assertEqual(self.check(rows, require=False), [])
        failures = self.check(rows, require=True)
        self.assertEqual(len(failures), 1)
        self.assertIn("matched 0 rows", failures[0])

    def test_malformed_baseline_section_fails(self):
        failures = self.check([obs_row()],
                              base={"serving_obs": [obs_row()]})
        self.assertTrue(any("object of gates" in f for f in failures))


def kernel_row(kernel, backend, gflops, m=256, k=3072, n=64, threads=1):
    return {"kernel": kernel, "backend": backend, "threads": threads,
            "m": m, "k": k, "n": n, "mean_ns": 1.0, "min_ns": 1.0,
            "gflops": gflops}


class RelativeKernelGate(unittest.TestCase):
    def check(self, rows):
        fresh = {br.row_key(r): r for r in rows}
        failures = []
        br.check_kernels(fresh, None, "BENCH_baseline.json", 0.2, 1.2,
                         failures)
        return failures

    def test_tn_pair_is_gated(self):
        # A packed TN that lost its A-pack advantage must fail the gate.
        failures = self.check([
            kernel_row("tn", "tiled", 10.0),
            kernel_row("tn", "packed", 10.5),
        ])
        self.assertTrue(any("tn" in f and "1.2x gate" in f
                            for f in failures))

    def test_fast_tn_pair_passes(self):
        failures = self.check([
            kernel_row("tn", "tiled", 10.0),
            kernel_row("tn", "packed", 20.0),
        ])
        self.assertEqual(failures, [])

    def wide_short(self, backend, gflops, threads):
        m, k, n = br.WIDE_SHORT_SHAPE
        return kernel_row("nt", backend, gflops, m=m, k=k, n=n,
                          threads=threads)

    def test_wide_short_threaded_pair_is_gated(self):
        # At 4 rows the tiled backend cannot parallelize; a packed
        # backend whose per-block column parallelism regressed to the
        # tiled wall must fail the threaded relative gate.
        failures = self.check([
            self.wide_short("tiled", 10.0, 1),
            self.wide_short("packed", 15.0, 1),
            self.wide_short("tiled", 10.0, 0),
            self.wide_short("packed", 10.5, 0),
        ])
        self.assertEqual(len(failures), 1)
        self.assertIn("t0", failures[0])
        self.assertIn("1.2x gate", failures[0])

    def test_other_threaded_shapes_stay_ungated(self):
        # The auto-thread relative gate is pinned to the wide-short
        # shape; big square shapes at t0 keep their absolute floors
        # only (both backends parallelize there, the ratio is noise).
        failures = self.check([
            kernel_row("nn", "tiled", 10.0, m=1024, k=1024, n=1024,
                       threads=0),
            kernel_row("nn", "packed", 10.5, m=1024, k=1024, n=1024,
                       threads=0),
            # one serial pair so the vacuous-gate guard stays quiet
            kernel_row("nn", "tiled", 10.0),
            kernel_row("nn", "packed", 20.0),
        ])
        self.assertEqual(failures, [])


class EndToEnd(unittest.TestCase):
    def run_main(self, fresh_doc, baseline_doc, argv_tail):
        with tempfile.TemporaryDirectory() as td:
            fresh = os.path.join(td, "BENCH_linalg.json")
            baseline = os.path.join(td, "BENCH_baseline.json")
            with open(fresh, "w") as f:
                json.dump(fresh_doc, f)
            with open(baseline, "w") as f:
                json.dump(baseline_doc, f)
            old_argv = sys.argv
            sys.argv = ["bench_regression.py", "--fresh", fresh,
                        "--baseline", baseline] + argv_tail
            try:
                return br.main()
            finally:
                sys.argv = old_argv

    def test_tail_only_report_passes_without_require(self):
        rc = self.run_main({"serving_tail": [tail_row()]}, TAIL_BASE, [])
        self.assertEqual(rc, 0)

    def test_missing_tail_section_fails_under_require(self):
        # CI mode: a report whose serving_tail section vanished must
        # fail, not silently skip the fused-batching gate.
        doc = {"serving_tail": [tail_row()]}
        rc = self.run_main(doc, TAIL_BASE, ["--require-serving"])
        self.assertEqual(rc, 1, "other sections missing -> CI failure")
        del doc["serving_tail"]
        doc["serving"] = []
        rc = self.run_main(doc, TAIL_BASE, [])
        self.assertEqual(rc, 1, "an effectively empty report must fail")

    def test_methods_only_report_passes_and_is_named(self):
        import contextlib
        import io
        buf = io.StringIO()
        doc = {"serving_methods": methods_rows_all()}
        with contextlib.redirect_stdout(buf):
            rc = self.run_main(doc, METHODS_BASE, [])
        self.assertEqual(rc, 0)
        self.assertIn("gates evaluated: serving_methods", buf.getvalue())

    def test_degraded_method_row_fails_end_to_end(self):
        doc = {"serving_methods": methods_rows_all(
            batched_vs_sequential=1.0)}
        rc = self.run_main(doc, METHODS_BASE, [])
        self.assertEqual(rc, 1)

    def test_degraded_tail_row_fails(self):
        doc = {"serving_tail": [tail_row(fused_vs_per_adapter=0.9)]}
        rc = self.run_main(doc, TAIL_BASE, [])
        self.assertEqual(rc, 1)

    def test_quant_only_report_passes_and_is_named(self):
        import contextlib
        import io
        buf = io.StringIO()
        doc = {"serving_quant": quant_rows_all()}
        with contextlib.redirect_stdout(buf):
            rc = self.run_main(doc, QUANT_BASE, [])
        self.assertEqual(rc, 0)
        self.assertIn("gates evaluated: serving_quant", buf.getvalue())

    def test_degraded_quant_row_fails_end_to_end(self):
        doc = {"serving_quant": [
            quant_row("f32"),
            quant_row("bf16", capacity_vs_f32=1.2, rmse_vs_f32=0.004),
            quant_row("int8", capacity_vs_f32=3.5, rmse_vs_f32=0.02),
        ]}
        rc = self.run_main(doc, QUANT_BASE, [])
        self.assertEqual(rc, 1)

    def test_missing_quant_section_fails_under_require(self):
        # CI mode: scenario 7 vanishing must fail, not silently skip
        # the quantized-cache gate.
        doc = {"serving_tail": [tail_row()]}
        import contextlib
        import io
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            rc = self.run_main(doc, TAIL_BASE, ["--require-serving"])
        self.assertEqual(rc, 1)
        self.assertIn("serving_quant", buf.getvalue())

    def test_obs_only_report_passes_and_is_named(self):
        import contextlib
        import io
        buf = io.StringIO()
        doc = {"serving_obs": [obs_row()]}
        with contextlib.redirect_stdout(buf):
            rc = self.run_main(doc, OBS_BASE, [])
        self.assertEqual(rc, 0)
        self.assertIn("gates evaluated: serving_obs", buf.getvalue())

    def test_degraded_obs_row_fails_end_to_end(self):
        doc = {"serving_obs": [obs_row(traced_vs_untraced=0.7)]}
        rc = self.run_main(doc, OBS_BASE, [])
        self.assertEqual(rc, 1)

    def test_missing_obs_section_fails_under_require(self):
        # CI mode: scenario 8 vanishing must fail, not silently skip
        # the telemetry-overhead gate.
        doc = {"serving_tail": [tail_row()]}
        import contextlib
        import io
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            rc = self.run_main(doc, TAIL_BASE, ["--require-serving"])
        self.assertEqual(rc, 1)
        self.assertIn("serving_obs", buf.getvalue())

    def test_pass_names_the_gates_it_evaluated(self):
        # A PASS must say which gate sections actually ran, so a CI log
        # where a section silently vanished is distinguishable from a
        # full evaluation.
        import contextlib
        import io
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            rc = self.run_main({"serving_tail": [tail_row()]},
                               TAIL_BASE, [])
        self.assertEqual(rc, 0)
        out = buf.getvalue()
        self.assertIn("gates evaluated: serving_tail", out)
        self.assertNotIn("serving_wire", out.split("PASS")[-1],
                         "sections that did not run must not be listed")


if __name__ == "__main__":
    unittest.main()
