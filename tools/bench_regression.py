#!/usr/bin/env python3
"""Bench regression gate for the linalg kernels and the serving engine.

Compares a freshly generated `BENCH_linalg.json` (written by
`cargo bench --bench linalg_kernels` / `--bench serve_bench` to the
canonical repo-root path) against the committed `BENCH_baseline.json`.

Checks:

1. **Absolute kernel floors** — each `linalg_kernels` baseline row's
   `gflops` value.  The committed numbers are deliberately *conservative
   floors* (well below what a healthy run produces on any recent x86_64
   machine), because CI runners vary wildly; they exist to catch
   order-of-magnitude regressions (a kernel silently falling back to
   scalar loops, a packing bug exploding the memory traffic), not
   single-digit drift.  Regenerate with `--update` on a representative
   machine to tighten.

2. **Relative kernel gate** (machine-independent): within the fresh
   run, single-thread packed must beat single-thread tiled by >=
   MIN_RATIO on the NN, NT, and TN kernels at every measured shape
   (TN rides the same packed micro-kernel via a blocked A-operand
   transpose pack).  The acceptance target is 1.5x; the gate uses 1.2x
   to absorb runner noise.  The wide-short NT shape (4x512x3072, the
   serving decode panel) is additionally gated at auto threads: rows
   there are too few to parallelize, so packed only beats threaded
   tiled through its per-block column parallelism.

3. **Serving floors** — the `serving` section (written by
   `serve_bench`) is checked against the baseline's `serving` object:
   `throughput_rps` >= `throughput_rps_floor` and `p99_ms` <=
   `p99_ms_ceiling` for firehose rows (rate_rps == 0), both
   deliberately loose for runner noise.

4. **Relative serving gate** (machine-independent): the firehose row
   with >= MIN_SERVE_ADAPTERS adapters must show
   `batched_vs_sequential` >= `min_batched_vs_sequential` (the
   acceptance criterion: batched serving beats sequential per-request
   forward by 1.5x at 64 adapters).

5. **Model serving floors + shared-cache gate** — the `serving_model`
   section (written by serve_bench scenario 3: a whole adapted model,
   N sites x M adapters) is checked against the baseline's
   `serving_model` object: `throughput_rps` >= floor, `p99_ms` <=
   ceiling, and — machine-independent — `shared_vs_persite` >=
   `min_shared_vs_persite`: one shared projection-LRU budget across
   all sites must not lose to the same budget statically partitioned
   per site (the multi-site layer's reason to exist).

6. **Wire floors + edge-overhead gate** — the `serving_wire` section
   (written by serve_bench scenario 4: the single-site fleet served
   through a loopback HTTP gateway) is checked against the baseline's
   `serving_wire` object: `throughput_rps` >= floor, `p99_ms` <=
   ceiling, `errors` == 0 (every bench request must get a 200), and —
   machine-independent — `wire_vs_inprocess` >=
   `min_wire_vs_inprocess`: the HTTP + streaming-JSON edge must keep
   at least half the in-process engine's closed-loop throughput.

7. **Tail floors + fused-batching gate** — the `serving_tail` section
   (written by serve_bench scenario 5: the identical heavy-tail Zipf
   s=1.0 stream over a 512-adapter fleet through a fused cross-adapter
   server and a `fused = false` per-adapter-segment server) is checked
   against the baseline's `serving_tail` object: `throughput_rps` >=
   floor, `p99_ms` <= ceiling, and — machine-independent —
   `fused_vs_per_adapter` >= `min_fused_vs_per_adapter` (the
   acceptance criterion: fused batching beats per-adapter batching by
   1.5x on the tail workload; both walls come from the same binary on
   the same box, so the ratio is runner-independent).

8. **Cross-method gate** — the `serving_methods` section (written by
   serve_bench scenario 6: a mixed-method model serving CoSA, RoSA,
   and LoRA fleets side by side, one row per method plus a `mixed`
   row) is checked against the baseline's `serving_methods` object.
   Machine-independent and enforced by default: every acceptance row's
   `batched_vs_sequential` >= `min_batched_vs_sequential` (default
   1.2 — each method must still profit from the scheduler when the
   zoo shares one engine), and the `mixed` row must be present (the
   method-interleaved fused path is the acceptance criterion).
   Optional per-method `throughput_rps_floors` apply when committed.
   The CoSA-only `serving` / `serving_model` floors stay unchanged —
   this section gates the zoo, not the original single-method path.

9. **Quantized-cache gate** — the `serving_quant` section (written by
   serve_bench scenario 7: the 24-site x 64-adapter fleet driven at
   one thrashing LRU budget three times — f32, bf16, int8 cache
   codecs, one row per codec) is checked against the baseline's
   `serving_quant` object.  Machine-independent by construction (the
   metrics are exact resident counts and deterministic arithmetic):
   the bf16 row's `capacity_vs_f32` >= `min_capacity_vs_f32_bf16`
   (default 1.8 — half-width residents must nearly double effective
   cache capacity at the identical byte budget), and each row's
   `rmse_vs_f32` <= its `max_rmse_vs_f32` bound (f32 must be exactly
   0 — the default codec stays bit-identical).

10. **Telemetry-overhead gate** — the `serving_obs` section (written by
    serve_bench scenario 8: the scenario-1 fleet driven on one
    identical Zipf stream through an untraced server and a server with
    the full `obs` registry attached) is checked against the
    baseline's `serving_obs` object.  Machine-independent and enforced
    by default: `traced_vs_untraced` >= `min_traced_vs_untraced`
    (default 0.95 — stage spans, histograms, and the slow ring must
    cost less than 5% throughput; both walls come from the same binary
    on the same box, so the ratio is runner-independent), plus a
    conservative `throughput_rps_floor` on the traced half.

A fresh report that exists but is malformed (unparseable JSON, or none
of the expected sections with rows) is a hard failure — a silently
empty report must read as "the gate is off", never as "pass".  A
missing file still skips (local runs without a bench pass); CI passes
--require-serving so a vanished serving or serving_model section fails
there.

Exit codes: 0 ok / skipped (no fresh file), 1 regression or malformed
report.
"""

import argparse
import json
import os
import sys

SECTION = "linalg_kernels"
SERVING_SECTION = "serving"
MODEL_SECTION = "serving_model"
WIRE_SECTION = "serving_wire"
TAIL_SECTION = "serving_tail"
METHODS_SECTION = "serving_methods"
QUANT_SECTION = "serving_quant"
OBS_SECTION = "serving_obs"
TOLERANCE = 0.20          # max allowed drop below the baseline gflops
MIN_RATIO = 1.2           # fresh-run packed/tiled single-thread NN+NT floor
MIN_SERVE_ADAPTERS = 64   # fleet size the serving ratio gate applies to
# The one shape whose packed/tiled ratio is also gated at auto threads:
# 4 rows cannot be split across workers, so only the packed backend's
# per-block column parallelism keeps the threaded ratio healthy.
WIDE_SHORT_SHAPE = (4, 512, 3072)

KEY_FIELDS = ("kernel", "backend", "threads", "m", "k", "n")


def row_key(row):
    return tuple(row.get(f) for f in KEY_FIELDS)


def load_doc(path):
    """Parse `path` or die loudly — a malformed report is a failure,
    not a skip."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_regression: FAIL — cannot parse {path}: {e}")
        sys.exit(1)


def kernel_rows(doc):
    rows = doc.get(SECTION, [])
    if not isinstance(rows, list):
        return {}
    return {row_key(r): r for r in rows
            if isinstance(r, dict) and "gflops" in r}


def serving_rows(doc):
    rows = doc.get(SERVING_SECTION, [])
    if not isinstance(rows, list):
        return []
    return [r for r in rows
            if isinstance(r, dict) and "throughput_rps" in r]


def model_rows(doc):
    rows = doc.get(MODEL_SECTION, [])
    if not isinstance(rows, list):
        return []
    return [r for r in rows
            if isinstance(r, dict) and "throughput_rps" in r]


def wire_rows(doc):
    rows = doc.get(WIRE_SECTION, [])
    if not isinstance(rows, list):
        return []
    return [r for r in rows
            if isinstance(r, dict) and "throughput_rps" in r]


def tail_rows(doc):
    rows = doc.get(TAIL_SECTION, [])
    if not isinstance(rows, list):
        return []
    return [r for r in rows
            if isinstance(r, dict) and "throughput_rps" in r]


def methods_rows(doc):
    rows = doc.get(METHODS_SECTION, [])
    if not isinstance(rows, list):
        return []
    return [r for r in rows
            if isinstance(r, dict) and "throughput_rps" in r
            and "method" in r]


def quant_rows(doc):
    rows = doc.get(QUANT_SECTION, [])
    if not isinstance(rows, list):
        return []
    return [r for r in rows
            if isinstance(r, dict) and "rmse_vs_f32" in r
            and "kind" in r]


def obs_rows(doc):
    rows = doc.get(OBS_SECTION, [])
    if not isinstance(rows, list):
        return []
    return [r for r in rows
            if isinstance(r, dict) and "traced_vs_untraced" in r]


def find_fresh(candidates):
    for p in candidates:
        if os.path.exists(p):
            return p
    return None


def check_kernels(fresh, baseline_doc, baseline_path, tolerance, min_ratio,
                  failures):
    if baseline_doc is not None:
        baseline = kernel_rows(baseline_doc)
        compared = 0
        for key, base_row in sorted(baseline.items()):
            fresh_row = fresh.get(key)
            if fresh_row is None:
                print(f"  note: baseline row {key} missing from fresh run")
                continue
            compared += 1
            floor = base_row["gflops"] * (1.0 - tolerance)
            got = fresh_row["gflops"]
            tag = "/".join(str(k) for k in key)
            if got < floor:
                failures.append(
                    f"{tag}: {got:.2f} GFLOP/s < floor {floor:.2f} "
                    f"(baseline {base_row['gflops']:.2f} -{tolerance:.0%})")
            else:
                print(f"  ok: {tag}: {got:.2f} GFLOP/s (floor {floor:.2f})")
        print(f"bench_regression: {compared} kernel rows compared against "
              f"{baseline_path}")
    else:
        print(f"bench_regression: no {baseline_path} — absolute check "
              "skipped (generate one with --update)")

    # machine-independent relative gate: packed vs tiled, 1 thread —
    # plus the wide-short shape at auto threads, where the ratio is
    # carried by the packed backend's per-block column parallelism.
    relative_pairs = 0
    for key, tiled_row in sorted(fresh.items()):
        kernel, backend, threads = key[0], key[1], key[2]
        if backend != "tiled" or kernel not in ("nn", "nt", "tn"):
            continue
        if threads != 1 and not (threads == 0
                                 and key[3:] == WIDE_SHORT_SHAPE):
            continue
        packed_key = (kernel, "packed") + key[2:]
        packed_row = fresh.get(packed_key)
        if packed_row is None or tiled_row["gflops"] <= 0:
            continue
        relative_pairs += 1
        ratio = packed_row["gflops"] / tiled_row["gflops"]
        shape = "x".join(str(k) for k in key[3:])
        line = (f"{kernel} {shape} t{threads}: packed/tiled = "
                f"{ratio:.2f}x ({packed_row['gflops']:.2f} vs "
                f"{tiled_row['gflops']:.2f} GFLOP/s)")
        if ratio < min_ratio:
            failures.append(f"{line} — below the {min_ratio}x gate")
        else:
            print(f"  ok: {line}")
    if relative_pairs == 0:
        # A vacuous gate is a disabled gate: if a backend/field rename
        # leaves zero comparable packed/tiled pairs, fail loudly instead
        # of silently no longer enforcing the acceptance criterion.
        failures.append(
            "relative gate compared 0 packed-vs-tiled single-thread "
            "nn/nt/tn pairs — bench row keys no longer match this script")


def check_serving(rows, baseline_doc, baseline_path, require_acceptance,
                  failures):
    base = {}
    if baseline_doc is not None:
        base = baseline_doc.get(SERVING_SECTION, {})
    if not isinstance(base, dict):
        failures.append(f"{baseline_path}: `{SERVING_SECTION}` must be an "
                        "object of floors, not rows")
        return
    tp_floor = base.get("throughput_rps_floor", 0.0)
    p99_ceiling = base.get("p99_ms_ceiling", float("inf"))
    min_ratio = base.get("min_batched_vs_sequential", 1.5)
    # Shape keys pinning the floors to the committed scenario — the
    # analogue of the kernel checks keying rows by (m, k, n).
    want_shape = {k: base[k] for k in ("site_m", "site_n", "core_a",
                                       "core_b") if k in base}

    ratio_rows = 0
    for r in rows:
        tag = (f"serving[{r.get('adapters')} adapters, "
               f"rate {r.get('rate_rps')}]")
        firehose = not r.get("rate_rps")
        # Floors are calibrated for the committed acceptance workload
        # (>= MIN_SERVE_ADAPTERS adapters, firehose, baseline-declared
        # site/core shape).  Custom local scenarios (huge sites, paced
        # arrivals) are reported but not held to these numbers.
        shape_ok = all(r.get(k) == v for k, v in want_shape.items())
        if not firehose or r.get("adapters", 0) < MIN_SERVE_ADAPTERS \
                or not shape_ok:
            print(f"  note: {tag}: not the acceptance workload; floors "
                  "not applied")
            continue
        tp = r.get("throughput_rps", 0.0)
        if tp < tp_floor:
            failures.append(f"{tag}: throughput {tp:.0f} req/s < floor "
                            f"{tp_floor:.0f}")
        else:
            print(f"  ok: {tag}: throughput {tp:.0f} req/s "
                  f"(floor {tp_floor:.0f})")
        p99 = r.get("p99_ms", 0.0)
        if p99 > p99_ceiling:
            failures.append(f"{tag}: p99 {p99:.1f} ms > ceiling "
                            f"{p99_ceiling:.1f}")
        else:
            print(f"  ok: {tag}: p99 {p99:.1f} ms "
                  f"(ceiling {p99_ceiling:.1f})")
        # machine-independent ratio gate at the acceptance fleet size
        ratio_rows += 1
        ratio = r.get("batched_vs_sequential", 0.0)
        line = (f"{tag}: batched/sequential = {ratio:.2f}x "
                f"(gate {min_ratio}x)")
        if ratio < min_ratio:
            failures.append(f"{line} — batching no longer pays for itself")
        else:
            print(f"  ok: {line}")
    if ratio_rows == 0:
        # A local `cosa-repro serve-bench --adapters 16 ...` legitimately
        # writes a serving section without the acceptance workload; only
        # CI (--require-serving) insists the gate actually ran.
        msg = (f"serving gate matched 0 firehose rows with >= "
               f"{MIN_SERVE_ADAPTERS} adapters at the baseline shape — "
               "the acceptance workload (serve_bench scenario 1) did "
               "not run")
        if require_acceptance:
            failures.append(msg)
        else:
            print(f"  note: {msg}")


def check_serving_model(rows, baseline_doc, baseline_path,
                        require_acceptance, failures):
    base = {}
    if baseline_doc is not None:
        base = baseline_doc.get(MODEL_SECTION, {})
    if not isinstance(base, dict):
        failures.append(f"{baseline_path}: `{MODEL_SECTION}` must be an "
                        "object of floors, not rows")
        return
    tp_floor = base.get("throughput_rps_floor", 0.0)
    p99_ceiling = base.get("p99_ms_ceiling", float("inf"))
    min_shared = base.get("min_shared_vs_persite", 0.9)
    # Shape keys pinning the floors to the committed scenario.
    want_shape = {k: base[k] for k in ("sites", "adapters") if k in base}

    gated_rows = 0
    for r in rows:
        tag = (f"serving_model[{r.get('sites')} sites x "
               f"{r.get('adapters')} adapters]")
        shape_ok = all(r.get(k) == v for k, v in want_shape.items())
        if not shape_ok or r.get("rate_rps"):
            print(f"  note: {tag}: not the acceptance workload; floors "
                  "not applied")
            continue
        gated_rows += 1
        tp = r.get("throughput_rps", 0.0)
        if tp < tp_floor:
            failures.append(f"{tag}: throughput {tp:.0f} req/s < floor "
                            f"{tp_floor:.0f}")
        else:
            print(f"  ok: {tag}: throughput {tp:.0f} req/s "
                  f"(floor {tp_floor:.0f})")
        p99 = r.get("p99_ms", 0.0)
        if p99 > p99_ceiling:
            failures.append(f"{tag}: p99 {p99:.1f} ms > ceiling "
                            f"{p99_ceiling:.1f}")
        else:
            print(f"  ok: {tag}: p99 {p99:.1f} ms "
                  f"(ceiling {p99_ceiling:.1f})")
        # machine-independent: one shared LRU budget must not lose to
        # the same budget statically partitioned per site
        ratio = r.get("shared_vs_persite", 0.0)
        line = (f"{tag}: shared/persite cache = {ratio:.2f}x "
                f"(gate {min_shared}x)")
        if ratio < min_shared:
            failures.append(f"{line} — the shared projection cache lost "
                            "to static per-site partitioning")
        else:
            print(f"  ok: {line}")
    if gated_rows == 0:
        msg = (f"serving_model gate matched 0 firehose rows at the "
               f"baseline shape {want_shape} — the model acceptance "
               "workload (serve_bench scenario 3) did not run")
        if require_acceptance:
            failures.append(msg)
        else:
            print(f"  note: {msg}")


def check_serving_wire(rows, baseline_doc, baseline_path,
                       require_acceptance, failures):
    base = {}
    if baseline_doc is not None:
        base = baseline_doc.get(WIRE_SECTION, {})
    if not isinstance(base, dict):
        failures.append(f"{baseline_path}: `{WIRE_SECTION}` must be an "
                        "object of floors, not rows")
        return
    tp_floor = base.get("throughput_rps_floor", 0.0)
    p99_ceiling = base.get("p99_ms_ceiling", float("inf"))
    min_ratio = base.get("min_wire_vs_inprocess", 0.5)
    # Shape keys pinning the floors to the committed scenario.
    want_shape = {k: base[k] for k in ("adapters", "site_m", "site_n",
                                      "core_a", "core_b", "clients")
                  if k in base}

    gated_rows = 0
    for r in rows:
        tag = (f"serving_wire[{r.get('adapters')} adapters, "
               f"{r.get('clients')} clients]")
        shape_ok = all(r.get(k) == v for k, v in want_shape.items())
        if not shape_ok:
            print(f"  note: {tag}: not the acceptance workload; floors "
                  "not applied")
            continue
        gated_rows += 1
        errors = r.get("errors", 0)
        if errors:
            failures.append(f"{tag}: {errors} request error(s) — every "
                            "wire bench request must get a 200")
        else:
            print(f"  ok: {tag}: 0 request errors")
        tp = r.get("throughput_rps", 0.0)
        if tp < tp_floor:
            failures.append(f"{tag}: throughput {tp:.0f} req/s < floor "
                            f"{tp_floor:.0f}")
        else:
            print(f"  ok: {tag}: throughput {tp:.0f} req/s "
                  f"(floor {tp_floor:.0f})")
        p99 = r.get("p99_ms", 0.0)
        if p99 > p99_ceiling:
            failures.append(f"{tag}: p99 {p99:.1f} ms > ceiling "
                            f"{p99_ceiling:.1f}")
        else:
            print(f"  ok: {tag}: p99 {p99:.1f} ms "
                  f"(ceiling {p99_ceiling:.1f})")
        # machine-independent: the HTTP + JSON edge must keep at least
        # min_ratio of the in-process engine's closed-loop throughput
        ratio = r.get("wire_vs_inprocess", 0.0)
        line = (f"{tag}: wire/in-process = {ratio:.2f}x "
                f"(gate {min_ratio}x)")
        if ratio < min_ratio:
            failures.append(f"{line} — the wire edge eats too much of "
                            "the engine's throughput")
        else:
            print(f"  ok: {line}")
    if gated_rows == 0:
        msg = (f"serving_wire gate matched 0 rows at the baseline shape "
               f"{want_shape} — the wire acceptance workload "
               "(serve_bench scenario 4) did not run")
        if require_acceptance:
            failures.append(msg)
        else:
            print(f"  note: {msg}")


def check_serving_tail(rows, baseline_doc, baseline_path,
                       require_acceptance, failures):
    base = {}
    if baseline_doc is not None:
        base = baseline_doc.get(TAIL_SECTION, {})
    if not isinstance(base, dict):
        failures.append(f"{baseline_path}: `{TAIL_SECTION}` must be an "
                        "object of floors, not rows")
        return
    tp_floor = base.get("throughput_rps_floor", 0.0)
    p99_ceiling = base.get("p99_ms_ceiling", float("inf"))
    min_fused = base.get("min_fused_vs_per_adapter", 1.5)
    # Shape keys pinning the floors to the committed scenario (the
    # fused ratio only means something on the heavy-tail fleet).
    want_shape = {k: base[k] for k in ("sites", "adapters", "zipf")
                  if k in base}

    gated_rows = 0
    for r in rows:
        tag = (f"serving_tail[{r.get('sites')} sites x "
               f"{r.get('adapters')} adapters, zipf {r.get('zipf')}]")
        shape_ok = all(r.get(k) == v for k, v in want_shape.items())
        if not shape_ok:
            print(f"  note: {tag}: not the acceptance workload; floors "
                  "not applied")
            continue
        gated_rows += 1
        tp = r.get("throughput_rps", 0.0)
        if tp < tp_floor:
            failures.append(f"{tag}: throughput {tp:.0f} req/s < floor "
                            f"{tp_floor:.0f}")
        else:
            print(f"  ok: {tag}: throughput {tp:.0f} req/s "
                  f"(floor {tp_floor:.0f})")
        p99 = r.get("p99_ms", 0.0)
        if p99 > p99_ceiling:
            failures.append(f"{tag}: p99 {p99:.1f} ms > ceiling "
                            f"{p99_ceiling:.1f}")
        else:
            print(f"  ok: {tag}: p99 {p99:.1f} ms "
                  f"(ceiling {p99_ceiling:.1f})")
        # machine-independent: fused cross-adapter batching must beat
        # per-adapter-segment batching on the identical tail stream
        ratio = r.get("fused_vs_per_adapter", 0.0)
        line = (f"{tag}: fused/per-adapter = {ratio:.2f}x "
                f"(gate {min_fused}x)")
        if ratio < min_fused:
            failures.append(f"{line} — cross-adapter fusion no longer "
                            "pays for itself on the tail workload")
        else:
            print(f"  ok: {line}")
    if gated_rows == 0:
        msg = (f"serving_tail gate matched 0 rows at the baseline shape "
               f"{want_shape} — the tail acceptance workload "
               "(serve_bench scenario 5) did not run")
        if require_acceptance:
            failures.append(msg)
        else:
            print(f"  note: {msg}")


def check_serving_methods(rows, baseline_doc, baseline_path,
                          require_acceptance, failures):
    base = {}
    if baseline_doc is not None:
        base = baseline_doc.get(METHODS_SECTION, {})
    if not isinstance(base, dict):
        failures.append(f"{baseline_path}: `{METHODS_SECTION}` must be an "
                        "object of floors, not rows")
        return
    # The ratio gate is on even with no committed baseline object —
    # each method profiting from batching is the acceptance criterion,
    # not a tunable floor.
    min_ratio = base.get("min_batched_vs_sequential", 1.2)
    tp_floors = base.get("throughput_rps_floors", {})
    if not isinstance(tp_floors, dict):
        failures.append(f"{baseline_path}: `{METHODS_SECTION}."
                        "throughput_rps_floors` must map method -> floor")
        return
    # Shape keys pinning the gate to the committed scenario.
    want_shape = {k: base[k] for k in ("sites", "zipf") if k in base}

    gated = []
    for r in rows:
        method = r.get("method")
        tag = (f"serving_methods[{method}, {r.get('sites')} sites x "
               f"{r.get('adapters')} adapters]")
        shape_ok = all(r.get(k) == v for k, v in want_shape.items())
        if not shape_ok:
            print(f"  note: {tag}: not the acceptance workload; gate "
                  "not applied")
            continue
        gated.append(method)
        ratio = r.get("batched_vs_sequential", 0.0)
        line = (f"{tag}: batched/sequential = {ratio:.2f}x "
                f"(gate {min_ratio}x)")
        if ratio < min_ratio:
            failures.append(f"{line} — method `{method}` no longer "
                            "profits from the shared engine's batching")
        else:
            print(f"  ok: {line}")
        floor = tp_floors.get(method)
        if floor is not None:
            tp = r.get("throughput_rps", 0.0)
            if tp < floor:
                failures.append(f"{tag}: throughput {tp:.0f} req/s < "
                                f"floor {floor:.0f}")
            else:
                print(f"  ok: {tag}: throughput {tp:.0f} req/s "
                      f"(floor {floor:.0f})")
    if gated and "mixed" not in gated:
        failures.append(
            "serving_methods: no `mixed` row at the acceptance shape — "
            "the method-interleaved fused path (the reason the zoo "
            "shares one engine) was not measured")
    if not gated:
        msg = (f"serving_methods gate matched 0 rows at the baseline "
               f"shape {want_shape} — the cross-method acceptance "
               "workload (serve_bench scenario 6) did not run")
        if require_acceptance:
            failures.append(msg)
        else:
            print(f"  note: {msg}")


def check_serving_quant(rows, baseline_doc, baseline_path,
                        require_acceptance, failures):
    base = {}
    if baseline_doc is not None:
        base = baseline_doc.get(QUANT_SECTION, {})
    if not isinstance(base, dict):
        failures.append(f"{baseline_path}: `{QUANT_SECTION}` must be an "
                        "object of gates, not rows")
        return
    # Both gates are on even with no committed baseline object — the
    # capacity multiplier and the error budget ARE the acceptance
    # criteria, not tunable runner floors (every metric in this section
    # is exact counts or deterministic arithmetic).
    min_capacity = base.get("min_capacity_vs_f32_bf16", 1.8)
    rmse_bounds = base.get("max_rmse_vs_f32",
                           {"f32": 0.0, "bf16": 0.03, "int8": 0.08})
    if not isinstance(rmse_bounds, dict):
        failures.append(f"{baseline_path}: `{QUANT_SECTION}."
                        "max_rmse_vs_f32` must map kind -> bound")
        return
    # Shape keys pinning the gates to the committed scenario (the
    # capacity ratio only means something at the thrashing budget).
    want_shape = {k: base[k] for k in ("sites", "adapters", "zipf")
                  if k in base}

    gated = []
    for r in rows:
        kind = r.get("kind")
        tag = (f"serving_quant[{kind}, {r.get('sites')} sites x "
               f"{r.get('adapters')} adapters]")
        shape_ok = all(r.get(k) == v for k, v in want_shape.items())
        if not shape_ok:
            print(f"  note: {tag}: not the acceptance workload; gate "
                  "not applied")
            continue
        gated.append(kind)
        if kind == "bf16":
            cap = r.get("capacity_vs_f32", 0.0)
            line = (f"{tag}: effective capacity = {cap:.2f}x f32 "
                    f"(gate {min_capacity}x)")
            if cap < min_capacity:
                failures.append(
                    f"{line} — half-width residents no longer multiply "
                    "the cache's effective capacity")
            else:
                print(f"  ok: {line}")
        bound = rmse_bounds.get(kind)
        if bound is not None:
            rmse = r.get("rmse_vs_f32", float("inf"))
            line = (f"{tag}: output RMSE vs f32 = {rmse:.3g} "
                    f"(bound {bound:g})")
            if rmse > bound:
                failures.append(f"{line} — the `{kind}` codec blew its "
                                "error budget")
            else:
                print(f"  ok: {line}")
    if gated and "bf16" not in gated:
        failures.append(
            "serving_quant: no `bf16` row at the acceptance shape — the "
            "capacity-multiplier gate (the quantized cache's reason to "
            "exist) was not measured")
    if not gated:
        msg = (f"serving_quant gate matched 0 rows at the baseline "
               f"shape {want_shape} — the quantized-cache acceptance "
               "workload (serve_bench scenario 7) did not run")
        if require_acceptance:
            failures.append(msg)
        else:
            print(f"  note: {msg}")


def check_serving_obs(rows, baseline_doc, baseline_path,
                      require_acceptance, failures):
    base = {}
    if baseline_doc is not None:
        base = baseline_doc.get(OBS_SECTION, {})
    if not isinstance(base, dict):
        failures.append(f"{baseline_path}: `{OBS_SECTION}` must be an "
                        "object of gates, not rows")
        return
    # The overhead ratio gate is on even with no committed baseline
    # object — "tracing costs < 5% throughput" is the acceptance
    # criterion, not a tunable runner floor (both walls come from the
    # same binary on the same box).
    min_ratio = base.get("min_traced_vs_untraced", 0.95)
    tp_floor = base.get("throughput_rps_floor", 0.0)
    # Shape keys pinning the gate to the committed scenario.
    want_shape = {k: base[k] for k in ("adapters", "zipf") if k in base}

    gated_rows = 0
    for r in rows:
        tag = (f"serving_obs[{r.get('adapters')} adapters, "
               f"zipf {r.get('zipf')}]")
        shape_ok = all(r.get(k) == v for k, v in want_shape.items())
        if not shape_ok:
            print(f"  note: {tag}: not the acceptance workload; gate "
                  "not applied")
            continue
        gated_rows += 1
        # machine-independent: the traced server must keep >= min_ratio
        # of the untraced server's throughput on the identical stream
        ratio = r.get("traced_vs_untraced", 0.0)
        line = (f"{tag}: traced/untraced = {ratio:.3f}x "
                f"(gate {min_ratio}x)")
        if ratio < min_ratio:
            failures.append(f"{line} — request tracing eats too much of "
                            "the engine's throughput")
        else:
            print(f"  ok: {line}")
        tp = r.get("traced_throughput_rps", 0.0)
        if tp < tp_floor:
            failures.append(f"{tag}: traced throughput {tp:.0f} req/s < "
                            f"floor {tp_floor:.0f}")
        else:
            print(f"  ok: {tag}: traced throughput {tp:.0f} req/s "
                  f"(floor {tp_floor:.0f})")
    if gated_rows == 0:
        msg = (f"serving_obs gate matched 0 rows at the baseline shape "
               f"{want_shape} — the telemetry-overhead acceptance "
               "workload (serve_bench scenario 8) did not run")
        if require_acceptance:
            failures.append(msg)
        else:
            print(f"  note: {msg}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--fresh", default=None,
                    help="fresh BENCH_linalg.json (default: repo-root "
                         "BENCH_linalg.json, then rust/BENCH_linalg.json "
                         "for pre-canonical-path reports)")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    ap.add_argument("--min-ratio", type=float, default=MIN_RATIO)
    ap.add_argument("--require-serving", action="store_true",
                    help="fail (instead of noting) when the fresh report "
                         "has no serving rows — CI sets this")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline kernel rows from the fresh "
                         "run (serving floors stay hand-maintained)")
    args = ap.parse_args()

    fresh_path = args.fresh or find_fresh(
        ["BENCH_linalg.json", "rust/BENCH_linalg.json"])
    if fresh_path is None or not os.path.exists(fresh_path):
        if args.require_serving:
            # CI mode: a vanished report must read as "the gate is off",
            # never as a pass.
            print("bench_regression: FAIL — no fresh BENCH_linalg.json "
                  "found but --require-serving is set; the bench steps "
                  "did not produce the canonical report")
            return 1
        print("bench_regression: no fresh BENCH_linalg.json found — "
              "skipping (run `cargo bench --bench linalg_kernels` first)")
        return 0

    doc = load_doc(fresh_path)
    fresh = kernel_rows(doc)
    serving = serving_rows(doc)
    model = model_rows(doc)
    wire = wire_rows(doc)
    tail = tail_rows(doc)
    methods = methods_rows(doc)
    quant = quant_rows(doc)
    obs = obs_rows(doc)
    if (not fresh and not serving and not model and not wire and not tail
            and not methods and not quant and not obs):
        print(f"bench_regression: FAIL — {fresh_path} exists but has no "
              f"usable `{SECTION}`, `{SERVING_SECTION}`, "
              f"`{MODEL_SECTION}`, `{WIRE_SECTION}`, `{TAIL_SECTION}`, "
              f"`{METHODS_SECTION}`, `{QUANT_SECTION}` or "
              f"`{OBS_SECTION}` rows; an empty report must not pass "
              "the gate")
        return 1

    if args.update:
        if not fresh:
            # A serving-only report must not blow away the committed
            # kernel floors — that would silently disable the kernel
            # gate forever after.
            print(f"bench_regression: FAIL — refusing --update: "
                  f"{fresh_path} has no `{SECTION}` rows (run "
                  "`cargo bench --bench linalg_kernels` first)")
            return 1
        baseline_doc = {}
        if os.path.exists(args.baseline):
            baseline_doc = load_doc(args.baseline)
        baseline_doc[SECTION] = doc.get(SECTION, [])
        with open(args.baseline, "w") as f:
            json.dump(baseline_doc, f, indent=1, sort_keys=True)
        print(f"bench_regression: baseline updated from {fresh_path} "
              f"({len(baseline_doc[SECTION])} kernel rows; serving floors "
              "left as committed)")
        return 0

    # --require-serving is effectively "CI mode": every gated section
    # must be present.  Local runs that benched only one side get a note
    # for the missing section instead (the both-missing case already
    # failed above).
    baseline_doc = (load_doc(args.baseline)
                    if os.path.exists(args.baseline) else None)
    failures = []
    evaluated = []  # gate sections actually checked this run
    if fresh:
        evaluated.append(SECTION)
        check_kernels(fresh, baseline_doc, args.baseline, args.tolerance,
                      args.min_ratio, failures)
    elif args.require_serving:
        failures.append(f"{fresh_path}: `{SECTION}` section is missing or "
                        "empty — did the kernel bench run?")
    else:
        print(f"bench_regression: note — no `{SECTION}` rows; kernel "
              "checks skipped")
    if serving:
        evaluated.append(SERVING_SECTION)
        check_serving(serving, baseline_doc, args.baseline,
                      args.require_serving, failures)
    elif args.require_serving:
        failures.append(f"{fresh_path}: `{SERVING_SECTION}` section is "
                        "missing or empty — did serve_bench run?")
    else:
        print(f"bench_regression: note — no `{SERVING_SECTION}` rows; "
              "serving checks skipped (CI runs with --require-serving)")
    if model:
        evaluated.append(MODEL_SECTION)
        check_serving_model(model, baseline_doc, args.baseline,
                            args.require_serving, failures)
    elif args.require_serving:
        failures.append(f"{fresh_path}: `{MODEL_SECTION}` section is "
                        "missing or empty — did serve_bench scenario 3 "
                        "run?")
    else:
        print(f"bench_regression: note — no `{MODEL_SECTION}` rows; "
              "model serving checks skipped (CI runs with "
              "--require-serving)")
    if wire:
        evaluated.append(WIRE_SECTION)
        check_serving_wire(wire, baseline_doc, args.baseline,
                           args.require_serving, failures)
    elif args.require_serving:
        failures.append(f"{fresh_path}: `{WIRE_SECTION}` section is "
                        "missing or empty — did serve_bench scenario 4 "
                        "run?")
    else:
        print(f"bench_regression: note — no `{WIRE_SECTION}` rows; "
              "wire serving checks skipped (CI runs with "
              "--require-serving)")
    if tail:
        evaluated.append(TAIL_SECTION)
        check_serving_tail(tail, baseline_doc, args.baseline,
                           args.require_serving, failures)
    elif args.require_serving:
        failures.append(f"{fresh_path}: `{TAIL_SECTION}` section is "
                        "missing or empty — did serve_bench scenario 5 "
                        "run?")
    else:
        print(f"bench_regression: note — no `{TAIL_SECTION}` rows; "
              "fused-batching tail checks skipped (CI runs with "
              "--require-serving)")
    if methods:
        evaluated.append(METHODS_SECTION)
        check_serving_methods(methods, baseline_doc, args.baseline,
                              args.require_serving, failures)
    elif args.require_serving:
        failures.append(f"{fresh_path}: `{METHODS_SECTION}` section is "
                        "missing or empty — did serve_bench scenario 6 "
                        "run?")
    else:
        print(f"bench_regression: note — no `{METHODS_SECTION}` rows; "
              "cross-method checks skipped (CI runs with "
              "--require-serving)")
    if quant:
        evaluated.append(QUANT_SECTION)
        check_serving_quant(quant, baseline_doc, args.baseline,
                            args.require_serving, failures)
    elif args.require_serving:
        failures.append(f"{fresh_path}: `{QUANT_SECTION}` section is "
                        "missing or empty — did serve_bench scenario 7 "
                        "run?")
    else:
        print(f"bench_regression: note — no `{QUANT_SECTION}` rows; "
              "quantized-cache checks skipped (CI runs with "
              "--require-serving)")
    if obs:
        evaluated.append(OBS_SECTION)
        check_serving_obs(obs, baseline_doc, args.baseline,
                          args.require_serving, failures)
    elif args.require_serving:
        failures.append(f"{fresh_path}: `{OBS_SECTION}` section is "
                        "missing or empty — did serve_bench scenario 8 "
                        "run?")
    else:
        print(f"bench_regression: note — no `{OBS_SECTION}` rows; "
              "telemetry-overhead checks skipped (CI runs with "
              "--require-serving)")

    if failures:
        print("\nbench_regression: FAIL")
        for f in failures:
            print(f"  regression: {f}")
        return 1
    # Name the gates that actually ran: a PASS that silently evaluated
    # fewer sections than expected should be visible in the CI log.
    print("\nbench_regression: PASS — gates evaluated: "
          + ", ".join(evaluated))
    return 0


if __name__ == "__main__":
    sys.exit(main())
