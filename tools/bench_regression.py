#!/usr/bin/env python3
"""Bench regression gate for the linalg kernels.

Compares the `linalg_kernels` section of a freshly generated
`BENCH_linalg.json` (written by `cargo bench --bench linalg_kernels`)
against the committed `BENCH_baseline.json` and fails on a >20%
per-kernel GFLOP/s regression.

Two kinds of checks:

1. **Absolute floors** — each baseline row's `gflops` value.  The
   committed numbers are deliberately *conservative floors* (well below
   what a healthy run produces on any recent x86_64 machine), because CI
   runners vary wildly; they exist to catch order-of-magnitude
   regressions (a kernel silently falling back to scalar loops, a
   packing bug exploding the memory traffic), not single-digit drift.
   Regenerate with `--update` on a representative machine to tighten.

2. **Relative gate** (machine-independent): within the fresh run,
   single-thread packed must beat single-thread tiled by >= MIN_RATIO on
   the NN and NT kernels at every measured shape.  The acceptance target
   is 1.5x; the gate uses 1.2x to absorb runner noise.

Exit codes: 0 ok / skipped (no fresh file), 1 regression detected.
"""

import argparse
import json
import os
import sys

SECTION = "linalg_kernels"
TOLERANCE = 0.20   # max allowed drop below the baseline gflops
MIN_RATIO = 1.2    # fresh-run packed/tiled single-thread NN+NT floor

KEY_FIELDS = ("kernel", "backend", "threads", "m", "k", "n")


def row_key(row):
    return tuple(row.get(f) for f in KEY_FIELDS)


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get(SECTION, [])
    return {row_key(r): r for r in rows if "gflops" in r}


def find_fresh(candidates):
    for p in candidates:
        if os.path.exists(p):
            return p
    return None


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--fresh", default=None,
                    help="fresh BENCH_linalg.json (default: search "
                         "rust/BENCH_linalg.json, BENCH_linalg.json)")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    ap.add_argument("--min-ratio", type=float, default=MIN_RATIO)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh run")
    args = ap.parse_args()

    fresh_path = args.fresh or find_fresh(
        ["rust/BENCH_linalg.json", "BENCH_linalg.json"])
    if fresh_path is None or not os.path.exists(fresh_path):
        print("bench_regression: no fresh BENCH_linalg.json found — "
              "skipping (run `cargo bench --bench linalg_kernels` first)")
        return 0

    fresh = load_rows(fresh_path)
    if not fresh:
        print(f"bench_regression: {fresh_path} has no `{SECTION}` rows — "
              "skipping")
        return 0

    if args.update:
        with open(fresh_path) as f:
            section = json.load(f).get(SECTION, [])
        baseline_doc = {}
        if os.path.exists(args.baseline):
            with open(args.baseline) as f:
                baseline_doc = json.load(f)
        baseline_doc[SECTION] = section
        with open(args.baseline, "w") as f:
            json.dump(baseline_doc, f, indent=1, sort_keys=True)
        print(f"bench_regression: baseline updated from {fresh_path} "
              f"({len(section)} rows)")
        return 0

    failures = []

    # 1. absolute floors vs the committed baseline
    if os.path.exists(args.baseline):
        baseline = load_rows(args.baseline)
        compared = 0
        for key, base_row in sorted(baseline.items()):
            fresh_row = fresh.get(key)
            if fresh_row is None:
                print(f"  note: baseline row {key} missing from fresh run")
                continue
            compared += 1
            floor = base_row["gflops"] * (1.0 - args.tolerance)
            got = fresh_row["gflops"]
            tag = "/".join(str(k) for k in key)
            if got < floor:
                failures.append(
                    f"{tag}: {got:.2f} GFLOP/s < floor {floor:.2f} "
                    f"(baseline {base_row['gflops']:.2f} -{args.tolerance:.0%})")
            else:
                print(f"  ok: {tag}: {got:.2f} GFLOP/s "
                      f"(floor {floor:.2f})")
        print(f"bench_regression: {compared} rows compared against "
              f"{args.baseline}")
    else:
        print(f"bench_regression: no {args.baseline} — absolute check "
              "skipped (generate one with --update)")

    # 2. machine-independent relative gate: packed vs tiled, 1 thread
    relative_pairs = 0
    for key, tiled_row in sorted(fresh.items()):
        kernel, backend, threads = key[0], key[1], key[2]
        if backend != "tiled" or threads != 1 or kernel not in ("nn", "nt"):
            continue
        packed_key = (kernel, "packed") + key[2:]
        packed_row = fresh.get(packed_key)
        if packed_row is None or tiled_row["gflops"] <= 0:
            continue
        relative_pairs += 1
        ratio = packed_row["gflops"] / tiled_row["gflops"]
        shape = "x".join(str(k) for k in key[3:])
        line = (f"{kernel} {shape}: packed/tiled = {ratio:.2f}x "
                f"({packed_row['gflops']:.2f} vs "
                f"{tiled_row['gflops']:.2f} GFLOP/s)")
        if ratio < args.min_ratio:
            failures.append(f"{line} — below the {args.min_ratio}x gate")
        else:
            print(f"  ok: {line}")
    if relative_pairs == 0:
        # A vacuous gate is a disabled gate: if a backend/field rename
        # leaves zero comparable packed/tiled pairs, fail loudly instead
        # of silently no longer enforcing the acceptance criterion.
        failures.append(
            "relative gate compared 0 packed-vs-tiled single-thread "
            "nn/nt pairs — bench row keys no longer match this script")

    if failures:
        print("\nbench_regression: FAIL")
        for f in failures:
            print(f"  regression: {f}")
        return 1
    print("\nbench_regression: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
